"""Data Transformer (paper §3.1.2): per-partition streaming join of
operational records against the In-memory cache, fact-grain splitting
(Fig. 3: intersect production windows with equipment-status intervals) and
OEE KPI computation (§4: availability / performance / quality / OEE).

The numeric core is ONE fused dispatch over fixed-width arrays, routed
through the pluggable compute-backend layer (``repro.core.backend``):
``numpy`` reference, ``jax`` jitted (``transform_kernel`` below), or the
``hash_join`` + ``segment_kpi`` Pallas kernels on TPU.

Payload layouts (see configs.dod_etl.steelworks_config):
  production : (prod_id, equipment_id, txn_time, t_start, t_end, qty, speed, order_id)
  equipment  : (row_id, equipment_id, txn_time, t_start, t_end, status, max_speed, planned)
  quality    : (row_id, equipment_id, txn_time, prod_id, defects, grade, scrap, rework)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import InMemoryTable

EPS = 1e-6

FACT_COLUMNS = ("equipment_id", "t_start", "t_end", "availability",
                "performance", "quality", "oee", "seg_on", "seg_off", "valid")


def _transform_math(prod: jax.Array,
                    eq_keys: jax.Array, eq_vals: jax.Array, eq_txn: jax.Array,
                    q_keys: jax.Array, q_vals: jax.Array, q_txn: jax.Array,
                    join_depth: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Traced body shared by ``transform_kernel`` and
    ``transform_rollup_kernel`` — identical math, so fusing the rollup
    into the dispatch can never change the facts."""
    from repro.core.cache import lookup_ref

    equip_id = prod[:, 1].astype(jnp.int32)
    prod_id = prod[:, 0].astype(jnp.int32)

    eq_rows, eq_found, _ = lookup_ref(equip_id, eq_keys, eq_vals, eq_txn)
    q_rows, q_found, _ = lookup_ref(prod_id, q_keys, q_vals, q_txn)
    # normalized-model join chains (§4.1.4 complexity knob): every extra
    # hop re-probes the cache. The hop keys are independent of the probed
    # values, so all hops run as ONE flattened probe over [(jd-1)*n] keys —
    # identical probe count and results, but a single wide dispatch instead
    # of jd-1 narrow ones (narrow sequential probes thrash when worker
    # threads dispatch concurrently)
    if join_depth > 1:
        mod = jnp.int32(max(eq_keys.shape[0] // 4, 1))
        hop_keys = ((equip_id[None, :]
                     + jnp.arange(1, join_depth, dtype=jnp.int32)[:, None])
                    % mod)
        extra, _, _ = lookup_ref(hop_keys.reshape(-1),
                                 eq_keys, eq_vals, eq_txn)
        # 0 * sum(hops) == sum of the per-hop 0-weighted adds
        eq_rows = eq_rows + 0.0 * extra.reshape(
            join_depth - 1, equip_id.shape[0], -1).sum(axis=0)
    found = eq_found & q_found

    t_start, t_end = prod[:, 3], prod[:, 4]
    qty, speed = prod[:, 5], prod[:, 6]
    e_start, e_end = eq_rows[:, 3], eq_rows[:, 4]
    status = eq_rows[:, 5]
    max_speed = eq_rows[:, 6]
    planned = eq_rows[:, 7]
    defects, scrap = q_vals_cols(q_rows)

    # ---- fact-grain split (Fig. 3): production window vs status interval
    inter_lo = jnp.maximum(t_start, e_start)
    inter_hi = jnp.minimum(t_end, e_end)
    overlap = jnp.maximum(inter_hi - inter_lo, 0.0)
    duration = jnp.maximum(t_end - t_start, EPS)
    seg_on = jnp.where(status > 0.5, overlap, 0.0)
    seg_off = duration - seg_on

    # ---- OEE (TPM indicators, §4)
    availability = jnp.clip(seg_on / jnp.maximum(planned, EPS), 0.0, 1.0)
    performance = jnp.clip(qty / jnp.maximum(max_speed * duration, EPS),
                           0.0, 1.0)
    good = jnp.maximum(qty - defects - scrap, 0.0)
    quality = jnp.clip(good / jnp.maximum(qty, EPS), 0.0, 1.0)
    oee = availability * performance * quality

    facts = jnp.stack([
        prod[:, 1], t_start, t_end, availability, performance, quality, oee,
        seg_on, seg_off, found.astype(jnp.float32)], axis=-1)
    return facts, found


@functools.partial(jax.jit, static_argnames=("join_depth",))
def transform_kernel(prod: jax.Array,
                     eq_keys: jax.Array, eq_vals: jax.Array, eq_txn: jax.Array,
                     q_keys: jax.Array, q_vals: jax.Array, q_txn: jax.Array,
                     join_depth: int = 1) -> Tuple[jax.Array, jax.Array]:
    """prod: [n, 8] f32 production payloads. Returns (facts [n, 10] f32,
    found [n] bool). ``join_depth > 1`` replays the join chain to model
    normalized (ISA-95-style) schemas — §4.1.4's complexity knob."""
    return _transform_math(prod, eq_keys, eq_vals, eq_txn,
                           q_keys, q_vals, q_txn, join_depth)


def _transform_rollup(prod: jax.Array,
                      eq_keys: jax.Array, eq_vals: jax.Array,
                      eq_txn: jax.Array,
                      q_keys: jax.Array, q_vals: jax.Array,
                      q_txn: jax.Array,
                      join_depth: int = 1, n_units: int = 1
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    facts, found = _transform_math(prod, eq_keys, eq_vals, eq_txn,
                                   q_keys, q_vals, q_txn, join_depth)
    unit = facts[:, 0].astype(jnp.int32)
    ok = found & (unit >= 0) & (unit < n_units)
    kpis = jnp.concatenate(
        [facts[:, 3:7], jnp.ones((facts.shape[0], 1), jnp.float32)],
        axis=-1)
    kpis = jnp.where(ok[:, None], kpis, 0.0)
    # rows failing the guard route to a trash segment past n_units
    agg = jax.ops.segment_sum(kpis, jnp.where(ok, unit, n_units),
                              num_segments=n_units + 1)[:n_units]
    return facts, found, agg


_ROLLUP_KERNEL_JIT = None


def transform_rollup_kernel(prod: jax.Array,
                            eq_keys: jax.Array, eq_vals: jax.Array,
                            eq_txn: jax.Array,
                            q_keys: jax.Array, q_vals: jax.Array,
                            q_txn: jax.Array,
                            join_depth: int = 1, n_units: int = 1
                            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The device-resident hot path's SINGLE dispatch: transform + per-unit
    KPI rollup fused. Returns (facts [n, 10] f32, found [n] bool,
    agg [n_units, 5] f32) where agg matches ``segment_reduce`` over the
    block's valid facts (pad rows carry unit -1 and drop out of the
    in-range guard, exactly like out-of-range units).

    Jitted lazily on first call: the padded production buffer is DONATED
    on real accelerators (a per-dispatch temporary, uploaded fresh each
    call, so XLA reuses its memory for the outputs) — but deciding that
    needs ``jax.default_backend()``, which initializes the platform, and
    an import-time call would lock the platform before callers can set
    XLA flags (CPU also warns on every donating compile)."""
    global _ROLLUP_KERNEL_JIT
    if _ROLLUP_KERNEL_JIT is None:
        donate = () if jax.default_backend() == "cpu" else (0,)
        _ROLLUP_KERNEL_JIT = functools.partial(
            jax.jit, static_argnames=("join_depth", "n_units"),
            donate_argnums=donate)(_transform_rollup)
    return _ROLLUP_KERNEL_JIT(prod, eq_keys, eq_vals, eq_txn,
                              q_keys, q_vals, q_txn,
                              join_depth=join_depth, n_units=n_units)


def q_vals_cols(q_rows: jax.Array) -> Tuple[jax.Array, jax.Array]:
    return q_rows[:, 4], q_rows[:, 6]


class DataTransformer:
    """Stateful wrapper: caches + late buffer + metrics for one worker.
    The numeric core is delegated to the selected ``ComputeBackend`` —
    one fused transform dispatch per call, regardless of how many queue
    partitions were coalesced into the batch. With ``n_units`` set the
    dispatch also carries the per-unit KPI rollup (the fused
    ``transform_and_rollup`` op), and the result stays device-resident
    as a ``FactBlock`` until the warehouse-load boundary."""

    def __init__(self, equipment: InMemoryTable, quality: InMemoryTable,
                 buffer, join_depth: int = 1, backend=None,
                 n_units: Optional[int] = None):
        from repro.core.backend import get_backend
        self.equipment = equipment
        self.quality = quality
        self.buffer = buffer
        self.join_depth = join_depth
        self.n_units = n_units    # fused-rollup width (None: facts only)
        self.backend = get_backend(backend)
        self.records_out = 0
        self.records_late = 0
        self.dispatches = 0     # device dispatch count (the tentpole metric)

    def watermark(self) -> int:
        return min(self.equipment.watermark, self.quality.watermark)

    def transform_block(self, batch, equipment=None, quality=None):
        """Pure numeric transform of a RecordBatch: ONE backend dispatch,
        no buffer interaction, NO host sync — returns a device-resident
        ``FactBlock`` (facts + found + fused per-unit rollup when
        ``n_units`` is configured). The concurrent runtime's transform
        stage calls this with immutable ``CacheSnapshot`` views (taken
        under the worker's cache lock) so the dispatch itself runs
        LOCK-FREE and overlaps the ingest stage's master pumps; the block
        materializes to host only in the load stage, under the worker's
        commit lock, so device compute + D2H overlap the load stage's
        host work instead of blocking here."""
        block = self.backend.transform_block(
            batch.payload,
            equipment if equipment is not None else self.equipment,
            quality if quality is not None else self.quality,
            join_depth=self.join_depth, n_units=self.n_units)
        self.dispatches += 1
        return block

    def process_block(self, prod_batch):
        """Retry-merge + dispatch WITHOUT the host sync: pops
        watermark-ready buffered records, concats them ahead of the new
        batch, issues one dispatch. Returns (block, merged_batch) —
        block is None when there was nothing to transform. ``finish``
        (or the load stage) completes the late-buffer accounting once the
        block is materialized."""
        from repro.core.records import RecordBatch

        retry = self.buffer.pop_ready(self.watermark())
        batch = RecordBatch.concat([retry, prod_batch])
        if not len(batch):
            return None, batch
        return self.transform_block(batch), batch

    def finish(self, block, batch) -> Tuple[np.ndarray, int]:
        """Host-side epilogue of ``process_block``: materialize the block
        (the step's one sync), buffer the late records, account metrics.
        Returns (good_facts [m, 10], n_late)."""
        facts, found = block.to_host()
        late = batch.filter(~found)
        self.buffer.push(late)
        self.records_late += len(late)
        good_facts = facts[found]
        self.records_out += len(good_facts)
        return good_facts, len(late)

    def process(self, prod_batch) -> Tuple[np.ndarray, int]:
        """prod_batch: RecordBatch of production records. Returns
        (facts [m, 10], n_late). Late records (missing master data) go to
        the Operational Message Buffer; buffered records whose txn_time
        passed the cache watermark are retried first (paper §3.1.2).

        Backends pad to power-of-two buckets internally so jitted kernels
        compile once per bucket, not once per arrival size (a 100x
        throughput cliff otherwise)."""
        block, batch = self.process_block(prod_batch)
        if block is None:
            return np.zeros((0, len(FACT_COLUMNS)), np.float32), 0
        return self.finish(block, batch)
