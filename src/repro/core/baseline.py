"""Baseline: the 'unmodified Stream Processing framework' of §4.1.1.

Same workload, same KPI math, but none of DOD-ETL's strategies:

  * no In-memory cache — every operational record looks master data up in
    the *source database* (per-record queries against production tables;
    this is also the source-overload pathology of Table 1),
  * no business-key partitioning — records are processed in arrival order
    on a single consumer view (no partition parallelism to exploit),
  * no late buffer — records with missing master data are retried by
    re-querying the source on the next micro-batch (the common
    polling-based design the paper replaces).

The 10x of Table 2 emerges mechanically: per-record host-side queries +
re-fetch per batch vs vectorized device probes against a worker-local cache.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.configs.dod_etl import ETLConfig
from repro.core.cdc import SourceDatabase
from repro.core.pipeline import StageMetrics
from repro.core.records import RecordBatch
from repro.core.transformer import FACT_COLUMNS, EPS


class BaselineStreamProcessor:
    def __init__(self, cfg: ETLConfig, source: SourceDatabase,
                 equipment_table: str = "equipment",
                 quality_table: str = "quality"):
        self.cfg = cfg
        self.source = source
        self.metrics = StageMetrics()
        self.pending: List[RecordBatch] = []
        names = [t.name for t in cfg.tables]
        self.eq_tid = names.index(equipment_table)
        self.q_tid = names.index(quality_table)
        self.rows_out = 0

    def process(self, batch: RecordBatch) -> np.ndarray:
        t0 = time.perf_counter()
        work = RecordBatch.concat(self.pending + [batch])
        self.pending = []
        n = len(work)
        facts = np.zeros((n, len(FACT_COLUMNS)), np.float32)
        late_idx = []
        for i in range(n):                       # record-at-a-time (paper §2)
            p = work.payload[i]
            equip_id = int(p[1])
            prod_id = int(p[0])
            # look-backs on the source database (the paper's anti-pattern)
            eq = self._query_master(self.eq_tid, "equipment_id", equip_id)
            qu = self._query_master(self.q_tid, "prod_id", prod_id)
            if eq is None or qu is None:
                late_idx.append(i)
                continue
            t_start, t_end, qty, speed = p[3], p[4], p[5], p[6]
            e_start, e_end, status, max_speed, planned = \
                eq[3], eq[4], eq[5], eq[6], eq[7]
            defects, scrap = qu[4], qu[6]
            overlap = max(min(t_end, e_end) - max(t_start, e_start), 0.0)
            duration = max(t_end - t_start, EPS)
            seg_on = overlap if status > 0.5 else 0.0
            availability = min(max(seg_on / max(planned, EPS), 0.0), 1.0)
            performance = min(max(qty / max(max_speed * duration, EPS), 0.0), 1.0)
            good = max(qty - defects - scrap, 0.0)
            quality = min(max(good / max(qty, EPS), 0.0), 1.0)
            oee = availability * performance * quality
            facts[i] = (p[1], t_start, t_end, availability, performance,
                        quality, oee, seg_on, duration - seg_on, 1.0)
        if late_idx:
            self.pending.append(work.take(np.array(late_idx, np.int64)))
        good_mask = facts[:, -1] > 0.5
        out = facts[good_mask]
        self.rows_out += len(out)
        self.metrics.records += len(out)
        self.metrics.wall_s += time.perf_counter() - t0
        return out

    def _query_master(self, table_id: int, join_col: str, join_key: int):
        """Per-record source query: full scan (no index on the join column —
        the paper's 'performance degradation' row of Table 1) returning the
        newest matching row by transaction time, like DOD-ETL's cache."""
        table = self.source.scan_table(table_id)
        txns = self.source.table_txn.get(table_id, {})
        col = 1 if join_col == "equipment_id" else 3
        best, best_t = None, -1
        for rk, row in table.items():
            if int(row[col]) == join_key and txns.get(rk, 0) > best_t:
                best, best_t = row, txns.get(rk, 0)
        return best
