"""Target Database Updater (paper §3.1.2): partition-parallel load of
transformed facts into the star-schema warehouse.

``StarSchemaWarehouse`` holds one fact table (OEE fact grains) plus the
equipment dimension; loads are per-partition appends (each partition
'executes its query statements independently'). ``query_oee`` is the OLAP
read path used by tests/examples to validate end-to-end correctness.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

import numpy as np

from repro.core.partitioning import partition_bounds
from repro.core.transformer import FACT_COLUMNS


class StarSchemaWarehouse:
    """Loads are thread-safe: the concurrent runtime's load stages append
    from one thread per worker, so the partition map, row counter and reads
    are guarded by a single lock (the numpy split work stays outside it)."""

    def __init__(self, backend=None):
        self._parts: Dict[int, List[np.ndarray]] = {}
        self._lock = threading.Lock()
        self.backend = backend       # pipeline's ComputeBackend (or None)
        self.rows_loaded = 0
        self.load_calls = 0

    def load(self, partition: int, facts: np.ndarray) -> None:
        if len(facts) == 0:
            return
        facts = np.asarray(facts)
        with self._lock:
            self._parts.setdefault(partition, []).append(facts)
            self.rows_loaded += len(facts)
            self.load_calls += 1

    def load_partitioned(self, facts: np.ndarray, n_partitions: int) -> int:
        """Split a coalesced fact block back per business-key partition
        (fact col 0 IS the business key) and append each slice — the ONLY
        point where the single-dispatch micro-batch re-partitions. The
        numpy split happens outside the lock; all partition appends then
        land under ONE acquisition (concurrent workers' load stages share
        this lock, so per-partition locking would contend ~n_partitions
        times per dispatch)."""
        n = len(facts)
        if n == 0:
            return 0
        order, bounds = partition_bounds(facts[:, 0].astype(np.int64),
                                         n_partitions)
        sorted_facts = facts[order]
        slices = [(p, sorted_facts[bounds[p]:bounds[p + 1]])
                  for p in range(n_partitions)
                  if bounds[p + 1] > bounds[p]]
        with self._lock:
            for p, chunk in slices:
                self._parts.setdefault(p, []).append(chunk)
                self.rows_loaded += len(chunk)
                self.load_calls += 1
        return n

    def kpi_rollup(self, n_units: int, backend=None) -> np.ndarray:
        """Per-equipment KPI sums [n_units, 5] (availability, performance,
        quality, oee, count) via the compute backend's segmented reduce.
        Selection: explicit arg > the pipeline's configured backend >
        env/default."""
        from repro.core.backend import get_backend
        be = get_backend(backend or self.backend)
        return be.segment_reduce(self.fact_table(), n_units)

    def fact_table(self) -> np.ndarray:
        with self._lock:
            chunks = [c for parts in self._parts.values() for c in parts]
        if not chunks:
            return np.zeros((0, len(FACT_COLUMNS)), np.float32)
        return np.concatenate(chunks)

    def canonical_fact_table(self) -> np.ndarray:
        """Fact table in a load-order-independent canonical order (full-row
        lexicographic sort). Two runs produced the same warehouse iff their
        canonical tables are byte-identical — the concurrency test's
        equality oracle, immune to thread interleaving of loads."""
        t = self.fact_table()
        if not len(t):
            return t
        return t[np.lexsort(t.T[::-1])]

    def query_oee(self, equipment_id: Optional[int] = None) -> Dict[str, float]:
        """OLAP aggregate: mean KPI per (optionally one) equipment unit."""
        t = self.fact_table()
        if equipment_id is not None:
            t = t[t[:, 0].astype(np.int64) == equipment_id]
        if len(t) == 0:
            return {k: float("nan") for k in
                    ("availability", "performance", "quality", "oee", "rows")}
        return {
            "availability": float(t[:, 3].mean()),
            "performance": float(t[:, 4].mean()),
            "quality": float(t[:, 5].mean()),
            "oee": float(t[:, 6].mean()),
            "rows": float(len(t)),
        }
