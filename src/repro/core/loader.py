"""Target Database Updater (paper §3.1.2): partition-parallel load of
transformed facts into the star-schema warehouse.

``StarSchemaWarehouse`` holds one fact table (OEE fact grains) plus the
equipment dimension; loads are per-partition appends (each partition
'executes its query statements independently'). ``query_oee`` is the OLAP
read path used by tests/examples to validate end-to-end correctness.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.partitioning import partition_bounds
from repro.core.transformer import FACT_COLUMNS


class StarSchemaWarehouse:
    def __init__(self, backend=None):
        self._parts: Dict[int, List[np.ndarray]] = {}
        self.backend = backend       # pipeline's ComputeBackend (or None)
        self.rows_loaded = 0
        self.load_calls = 0

    def load(self, partition: int, facts: np.ndarray) -> None:
        if len(facts) == 0:
            return
        self._parts.setdefault(partition, []).append(np.asarray(facts))
        self.rows_loaded += len(facts)
        self.load_calls += 1

    def load_partitioned(self, facts: np.ndarray, n_partitions: int) -> int:
        """Split a coalesced fact block back per business-key partition
        (fact col 0 IS the business key) and append each slice — the ONLY
        point where the single-dispatch micro-batch re-partitions."""
        n = len(facts)
        if n == 0:
            return 0
        order, bounds = partition_bounds(facts[:, 0].astype(np.int64),
                                         n_partitions)
        sorted_facts = facts[order]
        for p in range(n_partitions):
            lo, hi = bounds[p], bounds[p + 1]
            if hi > lo:
                self.load(p, sorted_facts[lo:hi])
        return n

    def kpi_rollup(self, n_units: int, backend=None) -> np.ndarray:
        """Per-equipment KPI sums [n_units, 5] (availability, performance,
        quality, oee, count) via the compute backend's segmented reduce.
        Selection: explicit arg > the pipeline's configured backend >
        env/default."""
        from repro.core.backend import get_backend
        be = get_backend(backend or self.backend)
        return be.segment_reduce(self.fact_table(), n_units)

    def fact_table(self) -> np.ndarray:
        chunks = [c for parts in self._parts.values() for c in parts]
        if not chunks:
            return np.zeros((0, len(FACT_COLUMNS)), np.float32)
        return np.concatenate(chunks)

    def query_oee(self, equipment_id: Optional[int] = None) -> Dict[str, float]:
        """OLAP aggregate: mean KPI per (optionally one) equipment unit."""
        t = self.fact_table()
        if equipment_id is not None:
            t = t[t[:, 0].astype(np.int64) == equipment_id]
        if len(t) == 0:
            return {k: float("nan") for k in
                    ("availability", "performance", "quality", "oee", "rows")}
        return {
            "availability": float(t[:, 3].mean()),
            "performance": float(t[:, 4].mean()),
            "quality": float(t[:, 5].mean()),
            "oee": float(t[:, 6].mean()),
            "rows": float(len(t)),
        }
