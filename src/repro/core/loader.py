"""Target Database Updater (paper §3.1.2): partition-parallel load of
transformed facts into the star-schema warehouse.

``StarSchemaWarehouse`` holds one fact table (OEE fact grains) plus the
equipment dimension; loads are per-partition appends (each partition
'executes its query statements independently'). Two read paths:

* the ad-hoc OLAP path (``query_oee`` / ``kpi_rollup`` / ``fact_table``)
  — full-rescan aggregates. All three read a pinned per-partition view of
  COMMITTED state (``read_view``): a load appends its chunks and bumps the
  commit sequence under one lock acquisition, and a view pins the chunk
  log at a commit boundary — so a report that issues several queries
  against one view can never observe a partition mid-``load`` from a
  concurrent worker (the torn-report race the serving layer also closes);

* the serving path — every load publishes its fact block (plus the
  records' CDC event-time stamps) as a delta to an attached
  ``repro.serving.MaterializedViewEngine``, which maintains report views
  incrementally in O(delta). ``attach_serving`` replays the committed
  chunk log first, so views cover history loaded before attachment.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partitioning import partition_bounds
from repro.core.transformer import FACT_COLUMNS


@dataclasses.dataclass(frozen=True)
class WarehouseView:
    """A pinned, immutable view of committed warehouse state: the chunk
    log as of one commit boundary. Everything a reader computes from one
    view is mutually consistent no matter how many concurrent loads land
    while it is held."""

    chunks: Tuple[np.ndarray, ...]           # committed fact blocks, in
    seq: int                                 # commit order
    rows: int


class StarSchemaWarehouse:
    """Loads are thread-safe: the concurrent runtime's load stages append
    from one thread per worker, so the chunk log, commit sequence and
    delta publication are guarded by a single lock (the numpy split work
    stays outside it)."""

    def __init__(self, backend=None):
        self._chunk_log: List[np.ndarray] = []   # committed blocks, in order
        self._lock = threading.Lock()
        self._serving = None                 # MaterializedViewEngine (opt.)
        self._shards = None                  # ShardOwnership (opt.)
        # per-shard chunk sub-logs, chunk-aligned: _shard_logs[k][i] holds
        # chunk i's rows whose business key the ownership routes to shard
        # k — maintained incrementally at commit time (the write path
        # never moves rows across shards)
        self._shard_logs: List[List[np.ndarray]] = []
        self.backend = backend       # pipeline's ComputeBackend (or None)
        self.rows_loaded = 0
        self.load_calls = 0
        self.commit_seq = 0
        # running per-unit KPI aggregate fed by the fused
        # transform_and_rollup dispatches (an O(1) read path next to the
        # kpi_rollup full rescan); rows loaded WITHOUT a rollup — legacy
        # per-partition loops, the record-at-a-time baseline — gap it
        self._kpi_running: Optional[np.ndarray] = None
        self._kpi_gap_rows = 0

    # ------------------------------------------------------------ serving hook
    def attach_serving(self, engine, replay_from: int = 0):
        """Wire a view engine: every committed load is published as one
        fact delta, in commit order (the publish happens under the load
        lock, so delta order == chunk-log order — what makes the engine's
        ``rebuild`` oracle byte-identical). History already loaded is
        replayed first, starting at chunk ``replay_from`` — recovery
        passes the engine's restored ``deltas_folded`` so only the
        post-checkpoint suffix replays (every committed chunk is
        non-empty, so chunk indices and delta sequence align 1:1).
        Idempotent for an already-attached engine (the recovery path
        attaches before handing the pipeline to a cluster whose
        constructor attaches again — a second full replay would
        double-fold history). Returns the engine for chaining."""
        with self._lock:
            if engine is self._serving:
                return engine
            for chunk in self._chunk_log[replay_from:]:
                engine.publish(chunk)
            self._serving = engine
        return engine

    # ------------------------------------------------------------- shard plane
    def _split_chunk(self, block: np.ndarray) -> None:
        """Lock-held: append one committed chunk's rows to the per-shard
        sub-logs (business key = fact col 0, routed through the attached
        ``ShardOwnership``). Row order within each shard's slice follows
        the chunk's order, so concatenating every shard's slices and
        canonical-sorting reproduces the chunk log byte-for-byte."""
        owner = self._shards.shard_of_keys(block[:, 0].astype(np.int64))
        for k in range(self._shards.n_shards):
            self._shard_logs[k].append(block[owner == k])

    def attach_shards(self, ownership) -> None:
        """Wire a ``repro.runtime.shard_plane.ShardOwnership``: every
        committed chunk is (and history retroactively gets) split into
        per-shard sub-logs, so each mesh shard holds only the fact rows
        of its owned business-key ranges. The primary chunk log — the
        commit/durability source of truth — is untouched; the split is a
        derived placement, which is what keeps the warehouse
        byte-identical to the unsharded one by construction."""
        with self._lock:
            self._shards = ownership
            self._shard_logs = [[] for _ in range(ownership.n_shards)]
            for chunk in self._chunk_log:
                self._split_chunk(chunk)

    def reown_shards(self, ownership) -> Dict:
        """Surgical re-split for a new routing epoch (the warehouse twin
        of ``ShardedViewEngine.reown``): chunks whose rows all keep their
        owner are left alone — only chunks containing a moved key have
        their per-shard slices rebuilt. Returns {chunks_resplit,
        rows_moved}. No-op unless shards are attached."""
        with self._lock:
            old = self._shards
            if old is None:
                return {"chunks_resplit": 0, "rows_moved": 0}
            K = ownership.n_shards
            if K != old.n_shards:
                raise ValueError(
                    f"reown_shards: shard count is fixed for the plane's "
                    f"lifetime ({old.n_shards} != {K}); detach and "
                    f"attach_shards to resize")
            resplit = 0
            moved_rows = 0
            for i, chunk in enumerate(self._chunk_log):
                keys = chunk[:, 0].astype(np.int64)
                ow_new = ownership.shard_of_keys(keys)
                moved = int((old.shard_of_keys(keys) != ow_new).sum())
                if not moved:
                    continue
                resplit += 1
                moved_rows += moved
                for k in range(K):
                    self._shard_logs[k][i] = chunk[ow_new == k]
            self._shards = ownership
            return {"chunks_resplit": resplit, "rows_moved": moved_rows}

    def shard_fact_table(self, shard: int) -> np.ndarray:
        """One shard's resident fact rows (its owned business-key ranges
        only), in commit order."""
        with self._lock:
            chunks = [c for c in self._shard_logs[shard] if len(c)]
            if not chunks:
                return np.zeros((0, len(FACT_COLUMNS)), np.float32)
            return np.concatenate(chunks)

    def shard_rows(self) -> List[int]:
        """[n_shards] resident row counts — the warehouse-side imbalance
        signal."""
        with self._lock:
            return [int(sum(len(c) for c in log))
                    for log in self._shard_logs]

    # ------------------------------------------------------------- durability
    def export_state(self, from_seq: int = 0) -> Dict:
        """Journal capture at a commit boundary: the chunk-log SUFFIX
        past ``from_seq`` (``commit_seq == len(_chunk_log)`` — one
        committed chunk per commit) plus the full counter state."""
        with self._lock:
            return {
                "chunks": list(self._chunk_log[from_seq:]),
                "seq": int(self.commit_seq),
                "rows": int(self.rows_loaded),
                "load_calls": int(self.load_calls),
                "kpi_running": (None if self._kpi_running is None
                                else self._kpi_running.copy()),
                "kpi_gap_rows": int(self._kpi_gap_rows),
            }

    def restore_state(self, state: Dict) -> None:
        """Cold-restart restore into an empty warehouse. ``state`` is the
        journal-accumulated form (chunks = the FULL committed log). Must
        run before ``attach_serving``: the chunks land silently, and the
        serving replay decides separately how much suffix to re-publish."""
        with self._lock:
            assert not self._chunk_log and self._serving is None, \
                "restore_state requires a fresh warehouse"
            chunks = [np.asarray(c, np.float32) for c in state["chunks"]]
            if len(chunks) != int(state["seq"]):
                raise IOError(
                    f"warehouse restore: {len(chunks)} chunks for commit "
                    f"seq {state['seq']}")
            self._chunk_log = chunks
            self.commit_seq = int(state["seq"])
            self.rows_loaded = int(state["rows"])
            self.load_calls = int(state["load_calls"])
            self._kpi_running = (None if state["kpi_running"] is None
                                 else np.asarray(state["kpi_running"]))
            self._kpi_gap_rows = int(state["kpi_gap_rows"])

    def _commit(self, block: np.ndarray,
                event_times: Optional[np.ndarray],
                rollup: Optional[np.ndarray] = None,
                routing_epoch: Optional[int] = None) -> None:
        """Lock-held: record the block in the committed chunk log, bump the
        commit sequence, fold the fused rollup into the running KPI
        aggregate, publish the delta (stamped with the routing epoch the
        records were processed under, for migration observability)."""
        self._chunk_log.append(block)
        if self._shards is not None:
            self._split_chunk(block)
        self.commit_seq += 1
        if rollup is not None:
            if self._kpi_running is None:
                self._kpi_running = np.zeros_like(rollup)
            if self._kpi_running.shape == rollup.shape:
                self._kpi_running = self._kpi_running + rollup
            else:                     # mixed n_units producers: no O(1) path
                self._kpi_gap_rows += len(block)
        else:
            self._kpi_gap_rows += len(block)
        if self._serving is not None:
            self._serving.publish(block, event_times,
                                  routing_epoch=routing_epoch)

    # -------------------------------------------------------------- load paths
    def load(self, partition: int, facts: np.ndarray,
             event_times: Optional[np.ndarray] = None,
             rollup: Optional[np.ndarray] = None,
             routing_epoch: Optional[int] = None) -> None:
        """Per-partition append (the caller already split by partition)."""
        if len(facts) == 0:
            return
        facts = np.asarray(facts)
        with self._lock:
            self.rows_loaded += len(facts)
            self.load_calls += 1
            self._commit(facts, event_times, rollup, routing_epoch)

    def load_partitioned(self, facts: np.ndarray, n_partitions: int,
                         event_times: Optional[np.ndarray] = None,
                         rollup: Optional[np.ndarray] = None,
                         routing_epoch: Optional[int] = None) -> int:
        """Group a coalesced fact block by business-key partition (fact
        col 0 IS the business key — each partition's rows land contiguous,
        'executing its query statements independently') and commit it as
        ONE block. The numpy sort happens outside the lock; the append,
        commit-sequence bump and serving delta land under ONE acquisition
        (concurrent workers' load stages share this lock, so per-partition
        locking would contend ~n_partitions times per dispatch — and a
        reader pinning a view can never see half a load).

        The chunk layout deliberately uses the STABLE static hash, never
        the queue's adaptive routing table: the grouping of one fact set
        is then invariant to routing epochs, so serving-view folds (whose
        segment ids come from fact columns alone — partition-stable by
        construction) and the chunk log replay stay byte-identical across
        repartitions. ``routing_epoch`` is carried as a stamp for
        observability only; it never influences the layout."""
        n = len(facts)
        if n == 0:
            return 0
        order, bounds = partition_bounds(facts[:, 0].astype(np.int64),
                                         n_partitions)
        sorted_facts = facts[order]
        sorted_times = (np.asarray(event_times, np.float64)[order]
                        if event_times is not None else None)
        n_hit = sum(1 for p in range(n_partitions)
                    if bounds[p + 1] > bounds[p])
        with self._lock:
            self.rows_loaded += n
            self.load_calls += n_hit     # one logical append per partition
            self._commit(sorted_facts, sorted_times, rollup, routing_epoch)
        return n

    # -------------------------------------------------------------- read paths
    def read_view(self) -> WarehouseView:
        """Pin the committed state at the current commit boundary. The
        returned chunks are the loaded arrays themselves (append-only, by
        convention never mutated) — pinning costs one tuple copy."""
        with self._lock:
            return WarehouseView(chunks=tuple(self._chunk_log),
                                 seq=self.commit_seq, rows=self.rows_loaded)

    def kpi_running(self) -> Optional[np.ndarray]:
        """The running per-unit KPI aggregate [n_units, 5] accumulated from
        the fused ``transform_and_rollup`` dispatches at load time — an
        O(1) read that never rescans the fact table. Returns None when any
        committed rows arrived without a rollup (legacy per-partition
        loops, the baseline), because the aggregate would under-count;
        ``kpi_rollup`` below remains the full-rescan oracle it is
        parity-tested against."""
        with self._lock:
            if self._kpi_running is None or self._kpi_gap_rows:
                return None
            return self._kpi_running.copy()

    def kpi_rollup(self, n_units: int, backend=None,
                   view: Optional[WarehouseView] = None) -> np.ndarray:
        """Per-equipment KPI sums [n_units, 5] (availability, performance,
        quality, oee, count) via the compute backend's segmented reduce —
        the full-rescan reference the serving layer's incremental views
        are parity-tested against. Selection: explicit arg > the
        pipeline's configured backend > env/default."""
        from repro.core.backend import get_backend
        be = get_backend(backend or self.backend)
        return be.segment_reduce(self.fact_table(view), n_units)

    def fact_table(self, view: Optional[WarehouseView] = None) -> np.ndarray:
        if view is None:
            view = self.read_view()
        if not view.chunks:
            return np.zeros((0, len(FACT_COLUMNS)), np.float32)
        return np.concatenate(view.chunks)

    def canonical_fact_table(self, view: Optional[WarehouseView] = None
                             ) -> np.ndarray:
        """Fact table in a load-order-independent canonical order (full-row
        lexicographic sort). Two runs produced the same warehouse iff their
        canonical tables are byte-identical — the concurrency test's
        equality oracle, immune to thread interleaving of loads."""
        t = self.fact_table(view)
        if not len(t):
            return t
        return t[np.lexsort(t.T[::-1])]

    def query_oee(self, equipment_id: Optional[int] = None,
                  view: Optional[WarehouseView] = None) -> Dict[str, float]:
        """OLAP aggregate: mean KPI per (optionally one) equipment unit.
        Pass one ``read_view()`` across several calls to make a multi-query
        report consistent under concurrent loads."""
        t = self.fact_table(view)
        if equipment_id is not None:
            t = t[t[:, 0].astype(np.int64) == equipment_id]
        if len(t) == 0:
            return {k: float("nan") for k in
                    ("availability", "performance", "quality", "oee", "rows")}
        return {
            "availability": float(t[:, 3].mean()),
            "performance": float(t[:, 4].mean()),
            "quality": float(t[:, 5].mean()),
            "oee": float(t[:, 6].mean()),
            "rows": float(len(t)),
        }
