"""Compute-backend layer: the pluggable numeric engine of the Stream
Processor (paper §3.3 technology-independence, made literal).

Every hot op of the Data Transformer / In-memory Table Updater is expressed
against the ``ComputeBackend`` protocol:

  * ``hash_probe``     — open-addressing probe of the in-memory master cache
                         (the streaming join of §3.1.2),
  * ``transform_block``— the fused fact-grain transform: both cache probes +
                         interval intersection (Fig. 3) + OEE KPI math (§4),
                         returning a device-resident ``FactBlock`` (and,
                         with ``n_units``, the per-unit KPI rollup in the
                         SAME dispatch — ``transform_and_rollup``),
  * ``transform``      — host-convenience wrapper: ``transform_block`` +
                         an immediate ``FactBlock.to_host()``,
  * ``segment_reduce`` — per-equipment KPI rollup of a fact block (the OLAP
                         aggregate the Target Database Updater feeds; the
                         hot path gets this fused into the transform
                         dispatch via ``transform_and_rollup``),
  * ``fold_segments``  — the serving layer's incremental-view delta fold:
                         fused multi-statistic segmented aggregate
                         (count + sum + min + max per segment per value
                         lane) of one fact delta, in ONE dispatch per
                         block, segment-COMPACTED: the tree folds only the
                         delta's live segments and scatters into the
                         packed table (``repro.serving.engine`` folds
                         these into materialized report views),
  * ``fold_segments_scan`` — the same delta fold expressed as a
                         ``jax.lax.associative_scan`` over bit-reversed
                         rows: BITWISE-identical to the halving tree (the
                         bit-reversal permutation makes the scan's
                         adjacent-pair combine order equal the tree's
                         stride-halving order), kept as a parity-proven
                         alternative (measured slower than the unrolled
                         tree on CPU hosts — see docs/BENCHMARKS.md),
  * ``batch_gather_stats`` — the batched read path's point-query op: ONE
                         gather dispatch answers a whole batch of
                         per-segment stat lookups (count/sum/min/max +
                         means) against a packed view table,
  * ``prefix_fold``    — the windowed read path's cumulative fold: all S
                         window prefixes of a packed view table combined
                         in one O(log S)-depth associative scan
                         (bitwise-equal to halving-tree-folded pow2
                         blocks chained in block order — the same
                         association ``_fold_blocks`` uses; oracle:
                         ``prefix_fold_reference``).

Three registered implementations:

  ``numpy``   pure-host reference (no jit, no device) — the oracle,
  ``jax``     jitted jnp (XLA; CPU/GPU/TPU via jax.default_backend),
  ``pallas``  TPU Pallas kernels (``hash_join`` / ``segment_kpi``),
              interpret-mode on CPU.

Selection order: explicit name > ``ETLConfig.backend`` > the
``DODETL_BACKEND`` environment variable > ``"jax"``. A fourth backend is a
subclass + ``@register_backend("name")`` — see ARCHITECTURE.md.

Protocol boundaries: inputs are host numpy arrays; ``transform_block``
returns an opaque ``FactBlock`` that stays device-resident (no blocking
``np.asarray`` sync) until ``to_host()`` is called at the warehouse-load
boundary, so XLA's async dispatch overlaps device compute with the load
stage's host work. The jax/pallas backends mirror the cache to device
lazily via ``InMemoryTable.device_state`` (component-dirty tracked, so
steady-state snapshots re-upload nothing).

Instrumentation: every backend instance counts ``op_dispatches`` (device
dispatch groups issued) and ``host_syncs`` (blocking device→host
materializations). The counters are advisory/single-threaded — the
dispatch-overhead benchmark and the tier-1 dispatch-count tests read them.
"""
from __future__ import annotations

import itertools
import os
from typing import Dict, Optional, Tuple, Type, Union

import numpy as np

from repro.core.records import PAYLOAD_WIDTH
from repro.observability.registry import global_registry

EPS = 1e-6
DEFAULT_BACKEND = "jax"
ENV_VAR = "DODETL_BACKEND"

# backends are process singletons (get_backend) but tests construct ad-hoc
# instances too; each instance gets its own registry shard so resets stay
# per-instance while the merged read path sums per-backend process totals
_BACKEND_SEQ = itertools.count()

# fact layout produced by every backend's ``transform`` (keep in sync with
# repro.core.transformer.FACT_COLUMNS)
N_FACT = 10
KPI_LANES = 5   # availability, performance, quality, oee, count

# ------------------------------------------------------------- fold layout
# ``fold_segments`` packs its fused statistics as one [n_segments, W] f32
# table, W = 1 + 3 * n_lanes: [count | sums(L) | mins(L) | maxs(L)].
# Empty segments carry count 0, sum 0, min +inf, max -inf — the identity
# elements, so folds combine associatively lane-by-lane.
FOLD_BLOCK = 2048   # max rows per fold dispatch (bounds the [B, S, L] temp)


def fold_width(n_lanes: int) -> int:
    return 1 + 3 * n_lanes


def empty_fold_state(n_segments: int, n_lanes: int) -> np.ndarray:
    """The fold identity: what every view's aggregate state starts as."""
    out = np.zeros((n_segments, fold_width(n_lanes)), np.float32)
    out[:, 1 + n_lanes:1 + 2 * n_lanes] = np.inf
    out[:, 1 + 2 * n_lanes:] = -np.inf
    return out


def combine_fold(state: np.ndarray, delta: np.ndarray) -> np.ndarray:
    """Associative combine of two packed fold tables (host, elementwise —
    the same ops in every backend, so combining is bitwise deterministic).
    Returns a NEW array; never mutates either input (the serving layer's
    published epochs are immutable)."""
    L = (state.shape[1] - 1) // 3
    out = np.empty_like(state)
    out[:, :1 + L] = state[:, :1 + L] + delta[:, :1 + L]          # count+sum
    out[:, 1 + L:1 + 2 * L] = np.minimum(state[:, 1 + L:1 + 2 * L],
                                         delta[:, 1 + L:1 + 2 * L])
    out[:, 1 + 2 * L:] = np.maximum(state[:, 1 + 2 * L:],
                                    delta[:, 1 + 2 * L:])
    return out


def _fold_tree_np(seg: np.ndarray, vals: np.ndarray,
                  n_segments: int) -> np.ndarray:
    """Reference fold of ONE padded power-of-two block: a fixed pairwise
    halving tree over the one-hot-masked lanes. Every op is an exact or
    correctly-rounded IEEE elementwise op applied in a shape-determined
    order, so the jax twin (same tree) produces bitwise-identical results —
    the property behind the serving layer's byte-identical
    incremental-vs-recompute equivalence tests. Rows with seg outside
    [0, n_segments) (including the -1 padding) contribute the identity."""
    onehot = seg[:, None] == np.arange(n_segments, dtype=seg.dtype)[None, :]
    oh = onehot.astype(np.float32)                       # [B, S]
    cnt = oh
    sums = oh[:, :, None] * vals[:, None, :]             # exact: x*{0,1}
    mins = np.where(onehot[:, :, None], vals[:, None, :],
                    np.float32(np.inf))
    maxs = np.where(onehot[:, :, None], vals[:, None, :],
                    np.float32(-np.inf))
    while cnt.shape[0] > 1:
        h = cnt.shape[0] // 2
        cnt = cnt[:h] + cnt[h:]
        sums = sums[:h] + sums[h:]
        mins = np.minimum(mins[:h], mins[h:])
        maxs = np.maximum(maxs[:h], maxs[h:])
    return np.concatenate([cnt[0][:, None], sums[0], mins[0], maxs[0]],
                          axis=1)


def _fold_blocks(seg: np.ndarray, vals: np.ndarray, n_segments: int,
                 tree) -> np.ndarray:
    """Shared delta driver, SEGMENT-COMPACTED: ``np.unique`` the delta's
    live segment ids, remap them to a dense [0, n_active) range, fold the
    halving tree over ``[block, n_active, lanes]`` instead of
    ``[block, n_segments, lanes]``, then scatter the folded columns back
    into the packed ``[n_segments, W]`` table. A delta touching 2 of 2048
    segments folds a 2-wide tree, not a 2048-wide one.

    Bitwise contract unchanged: the tree is elementwise per segment column
    (a segment's fold never reads another segment's lanes), so dropping
    inactive columns and scattering afterwards reproduces the uncompacted
    tree's per-segment op order EXACTLY — the numpy==jax bitwise
    determinism and ``rebuild()`` byte-identity properties survive
    (asserted against an uncompacted reference in tests/test_serving.py).

    Chunking as before: <= FOLD_BLOCK row blocks, each padded to a power of
    two with seg = -1 identity rows, partials chained in block order (host
    combine). The active-column count is padded to a power of two (>= 8,
    capped at n_segments) so jitted trees compile once per
    (rows, columns) bucket, not once per distinct delta sparsity."""
    seg = np.asarray(seg, np.int64)
    vals = np.asarray(vals, np.float32)
    if vals.ndim == 1:
        vals = vals[:, None]
    n, L = vals.shape
    out = empty_fold_state(n_segments, L)
    if n == 0:
        return out
    in_range = (seg >= 0) & (seg < n_segments)
    live = np.unique(seg[in_range])
    n_active = len(live)
    if n_active == 0:
        return out                       # nothing but identity rows
    n_fold = min(n_segments, max(8, 1 << (n_active - 1).bit_length()))
    # rows outside [0, n_segments) become -1 (identity), live ids become
    # their rank in the sorted live array — the compact column index.
    # Dense deltas (every segment live) skip the remap: rank == id.
    if n_active == n_segments:
        cseg = seg if in_range.all() else np.where(in_range, seg, -1)
    else:
        cseg = np.where(in_range, np.searchsorted(live, seg), -1)
    acc = empty_fold_state(n_fold, L)
    for lo in range(0, n, FOLD_BLOCK):
        s = cseg[lo:lo + FOLD_BLOCK]
        v = vals[lo:lo + FOLD_BLOCK]
        m = len(s)
        bucket = max(8, 1 << (m - 1).bit_length())
        if bucket != m:
            s = np.concatenate([s, np.full(bucket - m, -1, np.int64)])
            v = np.concatenate([v, np.zeros((bucket - m, L), np.float32)])
        acc = combine_fold(acc, tree(s, v, n_fold))
    out[live] = acc[:n_active]           # scatter into the packed table
    return out


_BITREV_CACHE: Dict[int, np.ndarray] = {}


def bitrev_permutation(n: int) -> np.ndarray:
    """Bit-reversal permutation of [0, n) for power-of-two ``n``.

    The load-bearing identity of the scan fold: the halving tree
    (``x[:h] ⊕ x[h:]`` repeated) applied to ``x`` combines exactly the
    same operand pairs, at the same tree levels, as the adjacent-pair
    tree (``x[0::2] ⊕ x[1::2]`` repeated) applied to ``x[bitrev]`` — and
    the adjacent-pair tree is precisely the reduction
    ``jax.lax.associative_scan`` computes for its last output element.
    Permuting rows first therefore makes the scan's reduction BITWISE
    equal to ``_fold_tree_np``'s halving tree."""
    if n & (n - 1):
        raise ValueError(f"bitrev needs a power of two, got {n}")
    cached = _BITREV_CACHE.get(n)
    if cached is None:
        bits = (n - 1).bit_length()
        idx = np.arange(n, dtype=np.int64)
        rev = np.zeros(n, np.int64)
        for b in range(bits):
            rev |= ((idx >> b) & 1) << (bits - 1 - b)
        rev.flags.writeable = False
        _BITREV_CACHE[n] = cached = rev
    return cached


def _fold_tree_scan_np(seg: np.ndarray, vals: np.ndarray,
                       n_segments: int) -> np.ndarray:
    """Scan-order twin of ``_fold_tree_np``: bit-reverse the (padded,
    power-of-two) rows, then reduce ADJACENT pairs — the combine order of
    ``jax.lax.associative_scan``'s final element. Bitwise-identical to the
    halving tree (see ``bitrev_permutation``), so it plugs into
    ``_fold_blocks`` under the same determinism contract."""
    rev = bitrev_permutation(len(seg))
    seg = seg[rev]
    vals = vals[rev]
    onehot = seg[:, None] == np.arange(n_segments, dtype=seg.dtype)[None, :]
    oh = onehot.astype(np.float32)
    cnt = oh
    sums = oh[:, :, None] * vals[:, None, :]
    mins = np.where(onehot[:, :, None], vals[:, None, :], np.float32(np.inf))
    maxs = np.where(onehot[:, :, None], vals[:, None, :], np.float32(-np.inf))
    while cnt.shape[0] > 1:
        cnt = cnt[0::2] + cnt[1::2]
        sums = sums[0::2] + sums[1::2]
        mins = np.minimum(mins[0::2], mins[1::2])
        maxs = np.maximum(maxs[0::2], maxs[1::2])
    return np.concatenate([cnt[0][:, None], sums[0], mins[0], maxs[0]],
                          axis=1)


# ------------------------------------------------- batched read-path helpers
def gather_width(n_lanes: int) -> int:
    """Row width of ``batch_gather_stats`` output:
    [count | sums(L) | mins(L) | maxs(L) | means(L)]."""
    return 1 + 4 * n_lanes


def _gather_stats_np(table: np.ndarray, idx: np.ndarray) -> np.ndarray:
    table = np.asarray(table, np.float32)
    idx = np.asarray(idx, np.int64)
    L = (table.shape[1] - 1) // 3
    t = table[idx]                                   # [B, 1 + 3L]
    cnt = t[:, :1]
    with np.errstate(invalid="ignore", divide="ignore"):
        means = np.where(cnt > 0, t[:, 1:1 + L] / cnt,
                         np.float32(np.nan))
    return np.concatenate([t, means], axis=1)        # [B, 1 + 4L]


def _combine_packed_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Vectorized ``combine_fold`` over leading axes (rows are packed
    [1 + 3L] fold vectors; the lane split is the last axis)."""
    L = (a.shape[-1] - 1) // 3
    return np.concatenate([
        a[..., :1 + L] + b[..., :1 + L],
        np.minimum(a[..., 1 + L:1 + 2 * L], b[..., 1 + L:1 + 2 * L]),
        np.maximum(a[..., 1 + 2 * L:], b[..., 1 + 2 * L:])], axis=-1)


def _assoc_scan_np(x: np.ndarray) -> np.ndarray:
    """Host twin of ``jax.lax.associative_scan`` (inclusive, axis 0) over
    packed fold rows — the SAME odd/even recursion, so results are bitwise
    identical to the jitted scan. Callers pad to a power of two first
    (every recursion level then stays even)."""
    n = x.shape[0]
    if n < 2:
        return x.copy()
    reduced = _combine_packed_np(x[0::2], x[1::2])
    odd = _assoc_scan_np(reduced)
    if n % 2 == 0:
        even = _combine_packed_np(odd[:-1], x[2::2])
    else:
        even = _combine_packed_np(odd, x[2::2])
    out = np.empty_like(x)
    out[0] = x[0]
    out[1::2] = odd
    out[2::2] = even
    return out


def prefix_fold_reference(table: np.ndarray) -> np.ndarray:
    """Recompute-from-scratch oracle for ``prefix_fold``: window ``w``'s
    cumulative aggregate built the way ``_fold_blocks`` chains blocks —
    split rows [0, w] into the power-of-two blocks of the binary
    decomposition of w+1 (largest first), reduce each block with the
    balanced adjacent-pair tree, and left-chain the block partials with
    the associative combine. ``jax.lax.associative_scan``'s inclusive
    prefixes use exactly this association, so ``prefix_fold`` must match
    BITWISE (asserted in tests and the scan-fold benchmark). O(S²) — an
    oracle, not a serving path."""
    table = np.asarray(table, np.float32)
    S = len(table)
    out = np.empty_like(table)
    for w in range(S):
        n = w + 1
        acc = None
        lo = 0
        for b in reversed(range(n.bit_length())):
            if (n >> b) & 1:
                blk = table[lo:lo + (1 << b)]
                while len(blk) > 1:          # balanced adjacent-pair tree
                    blk = _combine_packed_np(blk[0::2], blk[1::2])
                acc = blk[0] if acc is None \
                    else _combine_packed_np(acc, blk[0])
                lo += 1 << b
        out[w] = acc
    return out


def _prefix_fold_np(table: np.ndarray) -> np.ndarray:
    """Numpy ``prefix_fold``: pad the window axis to a power of two with
    fold-identity rows (an inclusive scan's prefix [w] never reads rows
    past w, so padding is invisible), run the associative-scan twin,
    slice. One pass, O(S log S) combines — vs O(S²) for S independent
    per-window refolds."""
    table = np.asarray(table, np.float32)
    S, W = table.shape
    if S == 0:
        return table.copy()
    L = (W - 1) // 3
    m = 1 << (S - 1).bit_length()
    if m != S:
        pad = np.broadcast_to(empty_fold_state(1, L), (m - S, W))
        table = np.concatenate([table, pad])
    return _assoc_scan_np(table)[:S]


class FactBlock:
    """Opaque handle to ONE transform dispatch's results — the unit of the
    device-resident hot path.

    For device backends (jax/pallas) ``facts``/``found`` (and the optional
    fused per-unit KPI ``rollup``) are device arrays: creating the block
    does NOT block on the dispatch, so the transform stage can hand the
    block downstream while XLA is still computing. ``start_host_copy()``
    enqueues the device→host copies asynchronously behind the compute;
    ``to_host()`` — called once, at the warehouse-load boundary —
    materializes and caches the host arrays (the step's single
    host↔device round trip, counted in ``backend.host_syncs``). For the
    numpy backend the arrays are already host-resident and ``to_host()``
    is free.

    ``n`` is the logical row count; device arrays may be padded to a
    power-of-two bucket, and ``to_host()`` slices the pad rows off."""

    __slots__ = ("_backend", "_facts", "_found", "_rollup", "n", "_host",
                 "_rollup_host")

    def __init__(self, backend: "ComputeBackend", facts, found, n: int,
                 rollup=None):
        self._backend = backend
        self._facts = facts
        self._found = found
        self._rollup = rollup
        self.n = int(n)
        self._host: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._rollup_host: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return self.n

    @property
    def backend(self) -> "ComputeBackend":
        return self._backend

    @property
    def device(self) -> bool:
        """True while the block's arrays live on device (not yet synced)."""
        return self._backend.device and self._host is None

    def start_host_copy(self) -> "FactBlock":
        """Enqueue the D2H copies behind the in-flight device compute
        WITHOUT blocking, so the copy overlaps downstream host work and the
        eventual ``to_host()`` finds the bytes already (or nearly) landed.
        No-op for host backends and already-materialized blocks."""
        if self._backend.device and self._host is None:
            for arr in (self._facts, self._found, self._rollup):
                start = getattr(arr, "copy_to_host_async", None)
                if start is not None:
                    start()
        return self

    def to_host(self) -> Tuple[np.ndarray, np.ndarray]:
        """Materialize (facts [n, N_FACT] f32, found [n] bool) on host.
        The FIRST call on a device block is the hot path's one blocking
        sync (counted in ``backend.host_syncs``); repeats are cached."""
        if self._host is None:
            if self._backend.device:
                self._backend.host_syncs += 1
            facts = np.asarray(self._facts)[:self.n]
            found = np.asarray(self._found)[:self.n]
            if self._rollup is not None and self._rollup_host is None:
                # tiny [n_units, KPI_LANES]; rides the same sync window
                self._rollup_host = np.asarray(self._rollup)
            self._host = (facts, found)
        return self._host

    def rollup_host(self) -> Optional[np.ndarray]:
        """The fused per-unit KPI rollup [n_units, KPI_LANES] (host), or
        None when the block was dispatched without one. After ``to_host``
        this is a cached tiny copy accounted with the block's single
        sync; called BEFORE ``to_host`` on a device block it must block
        on the whole dispatch, so it counts its own sync — the counter
        contract the tier-1 tests and CI dispatch gate pin stays honest
        under call reordering."""
        if self._rollup is None:
            return None
        if self._rollup_host is None:
            if self._backend.device and self._host is None:
                self._backend.host_syncs += 1
            self._rollup_host = np.asarray(self._rollup)
        return self._rollup_host


class ComputeBackend:
    """Protocol + shared helpers. Subclass and register to add a backend."""

    name: str = "abstract"
    device: bool = False     # True: wants the cache's device-mirrored state

    def __init__(self):
        # dispatch instrumentation lives on the process-wide metrics
        # registry (one read path with every other pipeline signal), one
        # shard per backend INSTANCE so per-instance counts/resets — the
        # contract the dispatch-count tests pin — are unchanged; the
        # registry merge sums instances into per-backend process totals
        # (``backend.<name>.op_dispatches``). The ``op_dispatches`` /
        # ``host_syncs`` properties keep the historical int-attribute
        # surface byte-for-byte.
        shard = global_registry().shard(
            f"backend.{self.name}#{next(_BACKEND_SEQ)}")
        self.metrics = shard
        self._op_dispatches = shard.counter(
            f"backend.{self.name}.op_dispatches")
        self._host_syncs = shard.counter(f"backend.{self.name}.host_syncs")

    @property
    def op_dispatches(self) -> int:
        """Device dispatch groups issued (single-threaded use: the
        dispatch benchmark + tier-1 dispatch-count tests)."""
        return self._op_dispatches.value

    @op_dispatches.setter
    def op_dispatches(self, v: int) -> None:
        self._op_dispatches.value = v

    @property
    def host_syncs(self) -> int:
        """Blocking device->host materializations."""
        return self._host_syncs.value

    @host_syncs.setter
    def host_syncs(self, v: int) -> None:
        self._host_syncs.value = v

    def reset_stats(self) -> None:
        self.op_dispatches = 0
        self.host_syncs = 0

    # ------------------------------------------------------------- protocol
    def hash_probe(self, query_keys, keys_tbl, vals_tbl, txn_tbl
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Linear-probe ``query_keys`` against an open-addressing table.
        Returns host (values [n, W] f32, found [n] bool, txn [n])."""
        raise NotImplementedError

    def transform_block(self, prod: np.ndarray, equipment, quality, *,
                        join_depth: int = 1,
                        n_units: Optional[int] = None) -> FactBlock:
        """Fused fact-grain transform of production payloads [n, 8] against
        the ``InMemoryTable`` caches, returned as a device-resident
        ``FactBlock`` (NO host sync). With ``n_units`` set, the SAME
        dispatch also produces the per-unit KPI rollup
        (``FactBlock.rollup_host()`` — ``segment_reduce`` semantics over
        the block's valid facts). ``join_depth > 1`` replays the probe
        chain (§4.1.4 complexity knob — numerically a no-op, cost is the
        point)."""
        raise NotImplementedError

    def transform(self, prod: np.ndarray, equipment, quality, *,
                  join_depth: int = 1
                  ) -> Tuple[np.ndarray, np.ndarray]:
        """Host-convenience transform: ``transform_block`` + an immediate
        ``to_host()``. Returns host (facts [n, N_FACT] f32, found [n]
        bool). The device-resident hot path uses ``transform_block``
        directly and defers the sync to the warehouse-load boundary."""
        return self.transform_block(prod, equipment, quality,
                                    join_depth=join_depth).to_host()

    def transform_and_rollup(self, prod: np.ndarray, equipment, quality, *,
                             n_units: int,
                             join_depth: int = 1) -> FactBlock:
        """Fused transform + per-unit KPI rollup in ONE dispatch: the
        block's facts/found plus ``rollup_host()`` ==
        ``segment_reduce(facts[found], n_units)`` (parity-tested like the
        other ops). The hot path's replacement for the separate
        transform-then-rollup round trips."""
        return self.transform_block(prod, equipment, quality,
                                    join_depth=join_depth, n_units=n_units)

    def segment_reduce(self, facts: np.ndarray, n_units: int) -> np.ndarray:
        """Per-equipment KPI rollup of a fact block: sums
        [availability, performance, quality, oee, count] over valid facts.
        Returns host [n_units, KPI_LANES] f32."""
        raise NotImplementedError

    def fold_segments(self, seg_ids: np.ndarray, values: np.ndarray,
                      n_segments: int) -> np.ndarray:
        """Fused multi-statistic delta fold for incremental materialized
        views: per segment, count + sum + min + max of every value lane in
        one dispatch per block, segment-compacted (see ``_fold_blocks``).
        ``seg_ids`` [n] int, ``values`` [n, L] f32; rows with seg outside
        [0, n_segments) contribute nothing. Returns the packed host table
        [n_segments, 1 + 3L] (see ``fold_width``)."""
        raise NotImplementedError

    def fold_segments_scan(self, seg_ids: np.ndarray, values: np.ndarray,
                           n_segments: int) -> np.ndarray:
        """``fold_segments`` with the per-block reduction expressed as an
        associative scan over bit-reversed rows instead of the unrolled
        halving tree (O(log n) combine depth either way; the scan form is
        the one scan-capable hardware pipelines). BITWISE-identical output
        to ``fold_segments`` — the bit-reversal permutation aligns the
        scan's adjacent-pair combine order with the tree's stride-halving
        order (see ``bitrev_permutation``). Measured slower than the
        unrolled tree on CPU hosts (XLA does not dead-code the scan's
        unused prefixes), so the tree stays the default write-side fold;
        this op is the parity-proven alternative and the form the
        windowed read path's ``prefix_fold`` shares its association
        with."""
        raise NotImplementedError

    def batch_gather_stats(self, table: np.ndarray,
                           seg_ids: np.ndarray) -> np.ndarray:
        """Batched point-query op of the read path: gather ``B`` segment
        rows from a packed ``[S, 1 + 3L]`` fold table and derive lane
        means, in ONE dispatch. ``seg_ids`` [B] int in [0, S). Returns
        host ``[B, 1 + 4L]`` f32: [count | sums | mins | maxs | means],
        means NaN where count == 0 (see ``gather_width``). Bitwise
        deterministic: the mean is the same single correctly-rounded f32
        divide the per-query path performs."""
        raise NotImplementedError

    def prefix_fold(self, table: np.ndarray) -> np.ndarray:
        """Cumulative windowed fold of the read path: inclusive running
        combine of a packed ``[S, 1 + 3L]`` view table along the window
        axis — row ``w`` of the result aggregates windows [0, w]. ONE
        O(log S)-depth associative scan answers every window prefix at
        once, replacing S independent per-window refolds (the S ≳ 128
        win). Bitwise-deterministic across numpy/jax and equal to
        ``prefix_fold_reference``. Returns host ``[S, 1 + 3L]`` f32."""
        raise NotImplementedError

    # ------------------------------------------------- device-mesh extension
    mesh = None   # optional 1-D device mesh (axis "shards") — see set_mesh

    def set_mesh(self, mesh) -> None:
        """Attach a 1-D device mesh (single axis, one device per serving
        shard) so ``fold_segments_sharded`` may run each shard's fold on
        its own device via ``shard_map``. ``None`` detaches. Backends
        without a device plane keep the host reference path; attaching a
        mesh never changes WHAT is computed (bitwise contract below)."""
        self.mesh = mesh

    def fold_segments_sharded(self, seg_ids: np.ndarray, values: np.ndarray,
                              n_segments: int, owners: np.ndarray,
                              n_shards: int) -> np.ndarray:
        """Shard-local delta folds for the sharded serving plane
        (``repro.runtime.shard_plane``): shard ``k`` folds the FULL delta
        with every segment it does not own masked to the -1 identity, so
        nothing crosses shards on the write path. ``owners`` [n_segments]
        int maps segment id -> owning shard. Returns the stacked host
        tables ``[n_shards, n_segments, 1 + 3L]``.

        Bitwise contract: the fold tree is elementwise per segment column
        (a segment's fold never reads another segment's lanes — the
        ``_fold_blocks`` compaction argument), so shard ``k``'s owned
        columns are bitwise identical to the single-device
        ``fold_segments`` columns, and its foreign columns are exactly
        the ``empty_fold_state`` identity. Reference implementation: one
        masked ``fold_segments`` per shard on the host."""
        seg = np.asarray(seg_ids, np.int64)
        owners = np.asarray(owners, np.int64)
        vals = np.asarray(values, np.float32)
        if vals.ndim == 1:
            vals = vals[:, None]
        in_range = (seg >= 0) & (seg < n_segments)
        own = np.where(in_range, owners[np.clip(seg, 0, n_segments - 1)], -1)
        return np.stack([
            self.fold_segments(np.where(own == k, seg, -1), vals, n_segments)
            for k in range(n_shards)])

    # -------------------------------------------------------------- helpers
    @staticmethod
    def _pad_bucket(prod: np.ndarray, floor: int = 1,
                    mutable: bool = False) -> np.ndarray:
        """Pad a payload block to a power-of-two bucket (>= floor) so jitted
        dispatch compiles once per bucket, not once per arrival size.

        When ``n`` already fills the bucket the input is returned as-is
        (zero-copy) — callers that WRITE into the padded block must pass
        ``mutable=True``, which guarantees the result never aliases the
        caller's array (a power-of-two-sized input used to come back
        aliased, and ``PallasBackend.segment_reduce`` scribbled on its
        caller's facts — see tests/test_backends.py regression)."""
        n = len(prod)
        bucket = max(floor, 1 << (n - 1).bit_length())
        if bucket == n:
            return prod.copy() if mutable else prod
        padrow = np.full((bucket - n, prod.shape[1]), -1.0, np.float32)
        return np.concatenate([prod, padrow])


_REGISTRY: Dict[str, Type[ComputeBackend]] = {}
_INSTANCES: Dict[str, ComputeBackend] = {}


def register_backend(name: str):
    def deco(cls: Type[ComputeBackend]) -> Type[ComputeBackend]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend_name(name: Optional[str] = None) -> str:
    return name or os.environ.get(ENV_VAR) or DEFAULT_BACKEND


def get_backend(name: Union[str, ComputeBackend, None] = None
                ) -> ComputeBackend:
    """Resolve a backend instance (singletons per name). Accepts an already
    constructed backend, a registered name, None (config/env default)."""
    if isinstance(name, ComputeBackend):
        return name
    resolved = resolve_backend_name(name)
    if resolved not in _REGISTRY:
        raise KeyError(f"unknown backend {resolved!r}; "
                       f"registered: {available_backends()}")
    if resolved not in _INSTANCES:
        _INSTANCES[resolved] = _REGISTRY[resolved]()
    return _INSTANCES[resolved]


# =========================================================== numpy backend
def _hash_probe_np(query_keys, keys_tbl, vals_tbl, txn_tbl):
    from repro.core.cache import MAX_PROBES, hash32_np
    keys_tbl = np.asarray(keys_tbl)
    vals_tbl = np.asarray(vals_tbl)
    txn_tbl = np.asarray(txn_tbl)
    n_slots = keys_tbl.shape[0]
    q = (np.asarray(query_keys).astype(np.int64)
         & 0xFFFFFFFF).astype(np.int32)
    h = (hash32_np(q) % np.uint32(n_slots)).astype(np.int64)
    n = len(q)
    done = np.zeros(n, bool)
    found = np.zeros(n, bool)
    val = np.zeros((n, vals_tbl.shape[1]), np.float32)
    txn = np.zeros(n, txn_tbl.dtype)
    for p in range(MAX_PROBES):
        cand = (h + p) % n_slots
        k = keys_tbl[cand]
        hit = (k == q) & ~done
        empty = (k == -1) & ~done
        if hit.any():
            val[hit] = vals_tbl[cand[hit]]
            txn[hit] = txn_tbl[cand[hit]]
            found |= hit
        done |= hit | empty
        if done.all():
            break
    return val, found, txn


def _segment_reduce_np(facts: np.ndarray, n_units: int) -> np.ndarray:
    facts = np.asarray(facts, np.float32)
    agg = np.zeros((n_units, KPI_LANES), np.float32)
    if not len(facts):
        return agg
    unit = facts[:, 0].astype(np.int64)
    # drop invalid facts AND out-of-range units, matching the jax/pallas
    # behavior (segment_sum / one-hot ignore ids outside [0, n_units))
    keep = (facts[:, 9] > 0.5) & (unit >= 0) & (unit < n_units)
    kpis = np.concatenate(
        [facts[keep, 3:7],
         np.ones((int(keep.sum()), 1), np.float32)], axis=-1)
    np.add.at(agg, unit[keep], kpis)
    return agg


@register_backend("numpy")
class NumpyBackend(ComputeBackend):
    """Pure-host reference. Mirrors the jitted math op-for-op in float32 so
    parity with jax/pallas holds to ~1e-6; the correctness oracle and the
    zero-dependency fallback. ``FactBlock``s are host-resident from birth
    (``to_host`` is free and counts no sync)."""

    device = False

    def hash_probe(self, query_keys, keys_tbl, vals_tbl, txn_tbl):
        self.op_dispatches += 1
        return _hash_probe_np(query_keys, keys_tbl, vals_tbl, txn_tbl)

    def transform_block(self, prod, equipment, quality, *, join_depth=1,
                        n_units=None):
        prod = np.asarray(prod, np.float32)
        eq_state = (equipment.keys, equipment.values, equipment.txn)
        q_state = (quality.keys, quality.values, quality.txn)
        equip_id = prod[:, 1].astype(np.int64)
        prod_id = prod[:, 0].astype(np.int64)
        eq_rows, eq_found, _ = _hash_probe_np(equip_id, *eq_state)
        q_rows, q_found, _ = _hash_probe_np(prod_id, *q_state)
        if join_depth > 1:            # flattened hop probe (cost knob;
            mod = max(len(eq_state[0]) // 4, 1)   # numeric no-op)
            hop_keys = ((equip_id[None, :]
                         + np.arange(1, join_depth)[:, None]) % mod)
            _hash_probe_np(hop_keys.reshape(-1), *eq_state)
        found = eq_found & q_found
        facts = _kpi_facts_np(prod, eq_rows, q_rows, found)
        rollup = (_segment_reduce_np(facts, n_units)
                  if n_units is not None else None)
        self.op_dispatches += 1       # the whole fused op: one "dispatch"
        return FactBlock(self, facts, found, len(prod), rollup)

    def segment_reduce(self, facts, n_units):
        self.op_dispatches += 1
        return _segment_reduce_np(facts, n_units)

    def fold_segments(self, seg_ids, values, n_segments):
        def tree(s, v, ns):
            self.op_dispatches += 1
            return _fold_tree_np(s, v, ns)
        return _fold_blocks(seg_ids, values, n_segments, tree)

    def fold_segments_scan(self, seg_ids, values, n_segments):
        def tree(s, v, ns):
            self.op_dispatches += 1
            return _fold_tree_scan_np(s, v, ns)
        return _fold_blocks(seg_ids, values, n_segments, tree)

    def batch_gather_stats(self, table, seg_ids):
        idx = np.asarray(seg_ids, np.int64)
        if not len(idx):
            L = (np.asarray(table).shape[1] - 1) // 3
            return np.zeros((0, gather_width(L)), np.float32)
        self.op_dispatches += 1
        return _gather_stats_np(table, idx)

    def prefix_fold(self, table):
        if not len(table):
            return np.asarray(table, np.float32).copy()
        self.op_dispatches += 1
        return _prefix_fold_np(table)


def _kpi_facts_np(prod, eq_rows, q_rows, found) -> np.ndarray:
    """Host twin of ``transformer.transform_kernel``'s KPI math (same op
    order in float32, so results agree with XLA to float rounding)."""
    f = np.float32
    t_start, t_end = prod[:, 3], prod[:, 4]
    qty = prod[:, 5]
    e_start, e_end = eq_rows[:, 3], eq_rows[:, 4]
    status = eq_rows[:, 5]
    max_speed = eq_rows[:, 6]
    planned = eq_rows[:, 7]
    defects, scrap = q_rows[:, 4], q_rows[:, 6]

    inter_lo = np.maximum(t_start, e_start)
    inter_hi = np.minimum(t_end, e_end)
    overlap = np.maximum(inter_hi - inter_lo, f(0.0))
    duration = np.maximum(t_end - t_start, f(EPS))
    seg_on = np.where(status > f(0.5), overlap, f(0.0))
    seg_off = duration - seg_on

    availability = np.clip(seg_on / np.maximum(planned, f(EPS)),
                           f(0.0), f(1.0))
    performance = np.clip(qty / np.maximum(max_speed * duration, f(EPS)),
                          f(0.0), f(1.0))
    good = np.maximum(qty - defects - scrap, f(0.0))
    quality = np.clip(good / np.maximum(qty, f(EPS)), f(0.0), f(1.0))
    oee = availability * performance * quality
    return np.stack([
        prod[:, 1], t_start, t_end, availability, performance, quality, oee,
        seg_on, seg_off, found.astype(np.float32)], axis=-1).astype(np.float32)


# ============================================================= jax backend
@register_backend("jax")
class JaxBackend(ComputeBackend):
    """Jitted jnp path (XLA). The default: one fused dispatch per worker per
    step, power-of-two bucket padding so steady-state recompiles are zero.
    ``transform_block`` returns without waiting on the dispatch — XLA's
    async dispatch runs the compute (and, after ``start_host_copy``, the
    D2H transfer) while the caller's host code keeps moving."""

    device = True

    def hash_probe(self, query_keys, keys_tbl, vals_tbl, txn_tbl):
        import jax.numpy as jnp
        from repro.core.cache import lookup_ref
        vals, found, txn = lookup_ref(
            jnp.asarray(np.asarray(query_keys), jnp.int32),
            keys_tbl, vals_tbl, txn_tbl)
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(vals), np.asarray(found), np.asarray(txn)

    def transform_block(self, prod, equipment, quality, *, join_depth=1,
                        n_units=None):
        import jax.numpy as jnp
        from repro.core.transformer import (transform_kernel,
                                            transform_rollup_kernel)
        prod = np.asarray(prod, np.float32)
        n = len(prod)
        padded = jnp.asarray(self._pad_bucket(prod, floor=128))
        eqk, eqv, eqt = equipment.device_state()
        qk, qv, qt = quality.device_state()
        if n_units is None:
            facts, found = transform_kernel(padded, eqk, eqv, eqt,
                                            qk, qv, qt,
                                            join_depth=join_depth)
            rollup = None
        else:
            facts, found, rollup = transform_rollup_kernel(
                padded, eqk, eqv, eqt, qk, qv, qt,
                join_depth=join_depth, n_units=n_units)
        self.op_dispatches += 1       # ONE fused XLA dispatch, zero syncs
        return FactBlock(self, facts, found, n, rollup)

    def segment_reduce(self, facts, n_units):
        import jax.numpy as jnp
        facts = np.asarray(facts, np.float32)
        if not len(facts):
            return np.zeros((n_units, KPI_LANES), np.float32)
        padded = self._pad_bucket(facts, floor=128)  # pads are valid=0 rows
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(_rollup_jnp(jnp.asarray(padded), n_units))

    def fold_segments(self, seg_ids, values, n_segments):
        # the jitted twin of the numpy halving tree: identical op order on
        # static shapes, so results are BITWISE equal to the numpy backend
        # (asserted by tests/test_serving.py) while the dispatch itself is
        # one fused XLA call per block (over the COMPACTED segment range —
        # see _fold_blocks)
        def tree(s, v, ns):
            import jax.numpy as jnp
            self.op_dispatches += 1
            self.host_syncs += 1
            return np.asarray(_fold_tree_jnp(jnp.asarray(s, jnp.int32),
                                             jnp.asarray(v), ns))
        return _fold_blocks(seg_ids, values, n_segments, tree)

    def fold_segments_scan(self, seg_ids, values, n_segments):
        # same compacted block driver; the per-block reduction is ONE
        # jax.lax.associative_scan over bit-reversed rows — bitwise equal
        # to the halving tree (see bitrev_permutation)
        def tree(s, v, ns):
            import jax.numpy as jnp
            self.op_dispatches += 1
            self.host_syncs += 1
            rev = bitrev_permutation(len(s))
            return np.asarray(_fold_tree_scan_jnp(
                jnp.asarray(s, jnp.int32), jnp.asarray(v),
                jnp.asarray(rev, jnp.int32), ns))
        return _fold_blocks(seg_ids, values, n_segments, tree)

    def batch_gather_stats(self, table, seg_ids):
        import jax.numpy as jnp
        idx = np.asarray(seg_ids, np.int64)
        n = len(idx)
        if not n:
            L = (np.asarray(table).shape[1] - 1) // 3
            return np.zeros((0, gather_width(L)), np.float32)
        # pow2 bucket so jit compiles once per batch-size bucket; pad ids
        # point at row 0 and the pad rows are sliced off after the sync
        bucket = max(8, 1 << (n - 1).bit_length())
        if bucket != n:
            idx = np.concatenate([idx, np.zeros(bucket - n, np.int64)])
        self.op_dispatches += 1
        self.host_syncs += 1
        out = np.asarray(_gather_stats_jnp(
            jnp.asarray(np.asarray(table, np.float32)),
            jnp.asarray(idx, jnp.int32)))
        return out[:n]

    def prefix_fold(self, table):
        import jax.numpy as jnp
        table = np.asarray(table, np.float32)
        S, W = table.shape
        if S == 0:
            return table.copy()
        L = (W - 1) // 3
        m = 1 << (S - 1).bit_length()
        if m != S:           # identity pad: inclusive prefixes never read it
            table = np.concatenate(
                [table, np.broadcast_to(empty_fold_state(1, L), (m - S, W))])
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(_prefix_fold_jnp(jnp.asarray(table)))[:S]

    def set_mesh(self, mesh):
        super().set_mesh(mesh)
        self._mesh_fold = None if mesh is None else _make_mesh_fold(mesh)

    def fold_segments_sharded(self, seg_ids, values, n_segments, owners,
                              n_shards):
        # mesh path: ONE shard_map dispatch per row block — every device
        # folds the (replicated) block against its own ownership mask,
        # device-local, no collectives. Falls back to the host reference
        # (one masked fold_segments per shard) when no matching mesh is
        # attached, so callers never branch.
        mesh = self.mesh
        if mesh is None or mesh.devices.size != n_shards:
            return super().fold_segments_sharded(
                seg_ids, values, n_segments, owners, n_shards)
        import jax.numpy as jnp
        seg = np.asarray(seg_ids, np.int64)
        vals = np.asarray(values, np.float32)
        if vals.ndim == 1:
            vals = vals[:, None]
        n, L = vals.shape
        out = np.stack([empty_fold_state(n_segments, L)] * n_shards)
        if n == 0:
            return out
        own_dev = jnp.asarray(np.asarray(owners, np.int64), jnp.int32)
        # same <= FOLD_BLOCK chunking + pow2 identity padding as
        # _fold_blocks, so per-column op order (and thus bytes) matches
        # the single-device fold exactly; the mesh tree is uncompacted
        # (static [block, n_segments] shape per device), which the
        # compaction contract makes bitwise-invisible
        for lo in range(0, n, FOLD_BLOCK):
            s = seg[lo:lo + FOLD_BLOCK]
            v = vals[lo:lo + FOLD_BLOCK]
            m = len(s)
            bucket = max(8, 1 << (m - 1).bit_length())
            if bucket != m:
                s = np.concatenate([s, np.full(bucket - m, -1, np.int64)])
                v = np.concatenate([v, np.zeros((bucket - m, L), np.float32)])
            self.op_dispatches += 1
            self.host_syncs += 1
            blk = np.asarray(self._mesh_fold(
                jnp.asarray(s, jnp.int32), jnp.asarray(v), own_dev,
                n_segments))
            for k in range(n_shards):
                out[k] = combine_fold(out[k], blk[k])
        return out


def _make_mesh_fold(mesh):
    """Build the jitted ``shard_map`` fold for one mesh: each device runs
    the SAME fixed halving tree as ``_fold_tree_jnp`` over the replicated
    block, with segments not owned by ``axis_index(shards)`` masked to the
    -1 identity first. Per owned segment column the op order is identical
    to the single-device tree, so the stacked [n_shards, S, W] output is
    bitwise the host reference (``ComputeBackend.fold_segments_sharded``).
    The body issues NO collectives — merging shard tables is the read
    path's explicit tree reduce, not the write path's job."""
    import functools

    import jax
    import jax.numpy as jnp
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axis = mesh.axis_names[0]

    @functools.partial(jax.jit, static_argnames=("n_segments",))
    def fold(seg, vals, owners, n_segments):
        def device_fold(seg, vals, owners):
            k = jax.lax.axis_index(axis).astype(jnp.int32)
            in_range = (seg >= 0) & (seg < n_segments)
            owner = jnp.where(in_range,
                              owners[jnp.clip(seg, 0, n_segments - 1)],
                              jnp.int32(-1))
            cseg = jnp.where(owner == k, seg, jnp.int32(-1))
            onehot = cseg[:, None] == jnp.arange(n_segments,
                                                 dtype=cseg.dtype)
            oh = onehot.astype(jnp.float32)
            cnt = oh
            sums = oh[:, :, None] * vals[:, None, :]
            mins = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
            maxs = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)
            while cnt.shape[0] > 1:
                h = cnt.shape[0] // 2
                cnt = cnt[:h] + cnt[h:]
                sums = sums[:h] + sums[h:]
                mins = jnp.minimum(mins[:h], mins[h:])
                maxs = jnp.maximum(maxs[:h], maxs[h:])
            table = jnp.concatenate(
                [cnt[0][:, None], sums[0], mins[0], maxs[0]], axis=1)
            return table[None]          # [1, S, W] -> stacked [K, S, W]
        return shard_map(device_fold, mesh,
                         in_specs=(P(), P(), P()),
                         out_specs=P(axis))(seg, vals, owners)

    return fold


_ROLLUP_JIT = None


def _rollup_jnp(facts, n_units: int):
    global _ROLLUP_JIT
    if _ROLLUP_JIT is None:
        import functools

        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("n_units",))
        def rollup(facts, n_units):
            unit = facts[:, 0].astype(jnp.int32)
            valid = facts[:, 9] > 0.5
            kpis = jnp.concatenate(
                [facts[:, 3:7], jnp.ones((facts.shape[0], 1), jnp.float32)],
                axis=-1)
            kpis = jnp.where(valid[:, None], kpis, 0.0)
            # invalid rows route to a trash segment past n_units
            return jax.ops.segment_sum(kpis, jnp.where(valid, unit, n_units),
                                       num_segments=n_units + 1)[:n_units]

        _ROLLUP_JIT = rollup
    return _ROLLUP_JIT(facts, n_units)


_FOLD_JIT = None


def _fold_tree_jnp(seg, vals, n_segments: int):
    """jnp twin of ``_fold_tree_np``: the SAME fixed halving tree of exact
    multiplies and correctly-rounded adds/min/max, so XLA produces bitwise
    the numpy result (the tree is shape-unrolled at trace time — one
    compile per (block, n_segments, lanes) bucket)."""
    global _FOLD_JIT
    if _FOLD_JIT is None:
        import functools

        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("n_segments",))
        def fold(seg, vals, n_segments):
            onehot = seg[:, None] == jnp.arange(n_segments, dtype=seg.dtype)
            oh = onehot.astype(jnp.float32)
            cnt = oh
            sums = oh[:, :, None] * vals[:, None, :]
            mins = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
            maxs = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)
            while cnt.shape[0] > 1:
                h = cnt.shape[0] // 2
                cnt = cnt[:h] + cnt[h:]
                sums = sums[:h] + sums[h:]
                mins = jnp.minimum(mins[:h], mins[h:])
                maxs = jnp.maximum(maxs[:h], maxs[h:])
            return jnp.concatenate(
                [cnt[0][:, None], sums[0], mins[0], maxs[0]], axis=1)

        _FOLD_JIT = fold
    return _FOLD_JIT(seg, vals, n_segments)


_SCAN_FOLD_JIT = None


def _fold_tree_scan_jnp(seg, vals, rev, n_segments: int):
    """Scan-form twin of ``_fold_tree_jnp``: one-hot the bit-reversed
    rows, then take the LAST element of an inclusive
    ``jax.lax.associative_scan`` — the scan's reduction combines adjacent
    pairs level by level, which on bit-reversed input is operand-for-
    operand the halving tree, so output is bitwise identical."""
    global _SCAN_FOLD_JIT
    if _SCAN_FOLD_JIT is None:
        import functools

        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("n_segments",))
        def fold(seg, vals, rev, n_segments):
            seg = seg[rev]
            vals = vals[rev]
            onehot = seg[:, None] == jnp.arange(n_segments, dtype=seg.dtype)
            oh = onehot.astype(jnp.float32)
            sums = oh[:, :, None] * vals[:, None, :]
            mins = jnp.where(onehot[:, :, None], vals[:, None, :], jnp.inf)
            maxs = jnp.where(onehot[:, :, None], vals[:, None, :], -jnp.inf)

            def comb(a, b):
                return (a[0] + b[0], a[1] + b[1],
                        jnp.minimum(a[2], b[2]), jnp.maximum(a[3], b[3]))

            c, s, mn, mx = jax.lax.associative_scan(
                comb, (oh, sums, mins, maxs), axis=0)
            return jnp.concatenate(
                [c[-1][:, None], s[-1], mn[-1], mx[-1]], axis=1)

        _SCAN_FOLD_JIT = fold
    return _SCAN_FOLD_JIT(seg, vals, rev, n_segments)


_GATHER_JIT = None


def _gather_stats_jnp(table, idx):
    """Jitted batched gather + means: the mean lane is the same single
    correctly-rounded f32 divide the per-query path performs, so results
    are bitwise equal to ``_gather_stats_np`` (NaN for empty segments)."""
    global _GATHER_JIT
    if _GATHER_JIT is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def gather(table, idx):
            L = (table.shape[1] - 1) // 3
            t = table[idx]                           # [B, 1 + 3L]
            cnt = t[:, :1]
            means = jnp.where(cnt > 0, t[:, 1:1 + L] / cnt, jnp.nan)
            return jnp.concatenate([t, means], axis=1)

        _GATHER_JIT = gather
    return _GATHER_JIT(table, idx)


_PREFIX_JIT = None


def _prefix_fold_jnp(table):
    """Jitted inclusive associative scan over packed fold rows (window
    axis). Same odd/even recursion as ``_assoc_scan_np`` — bitwise equal
    to the numpy backend and to ``prefix_fold_reference``."""
    global _PREFIX_JIT
    if _PREFIX_JIT is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def pf(table):
            L = (table.shape[1] - 1) // 3

            def comb(a, b):
                return jnp.concatenate([
                    a[..., :1 + L] + b[..., :1 + L],
                    jnp.minimum(a[..., 1 + L:1 + 2 * L],
                                b[..., 1 + L:1 + 2 * L]),
                    jnp.maximum(a[..., 1 + 2 * L:], b[..., 1 + 2 * L:])],
                    axis=-1)

            return jax.lax.associative_scan(comb, table, axis=0)

        _PREFIX_JIT = pf
    return _PREFIX_JIT(table)


# ========================================================== pallas backend
@register_backend("pallas")
class PallasBackend(ComputeBackend):
    """TPU Pallas kernels (``hash_join`` one-hot-MXU probe, ``segment_kpi``
    fused KPI + rollup). On CPU hosts the kernels run in interpret mode —
    slow but contract-identical, so parity tests cover the kernel path.

    ``transform_block`` issues a constant FEW dispatch groups (two probes,
    the optional hop probe, the fused KPI kernel) rather than jax's single
    one — the per-unit rollup still rides the ``segment_kpi`` kernel's
    fused epilogue, and the block stays device-resident with zero host
    syncs until ``to_host()``."""

    device = True

    def hash_probe(self, query_keys, keys_tbl, vals_tbl, txn_tbl):
        import jax.numpy as jnp
        from repro.kernels.hash_join.ops import hash_join
        vals, found, txn = hash_join(
            jnp.asarray(np.asarray(query_keys), jnp.int32),
            keys_tbl, vals_tbl, txn_tbl)
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(vals), np.asarray(found), np.asarray(txn)

    def transform_block(self, prod, equipment, quality, *, join_depth=1,
                        n_units=None):
        import jax.numpy as jnp
        from repro.kernels.hash_join.ops import hash_join
        from repro.kernels.segment_kpi.ops import segment_kpi
        prod = np.asarray(prod, np.float32)
        n = len(prod)
        padded = jnp.asarray(self._pad_bucket(prod, floor=256))
        eqk, eqv, eqt = equipment.device_state()
        qk, qv, qt = quality.device_state()
        equip_id = padded[:, 1].astype(jnp.int32)
        prod_id = padded[:, 0].astype(jnp.int32)
        eq_rows, eq_found, _ = hash_join(equip_id, eqk, eqv, eqt)
        q_rows, q_found, _ = hash_join(prod_id, qk, qv, qt)
        self.op_dispatches += 2
        if join_depth > 1:            # flattened hop probe (cost knob;
            mod = jnp.int32(max(eqk.shape[0] // 4, 1))  # numeric no-op)
            hop_keys = ((equip_id[None, :]
                         + jnp.arange(1, join_depth,
                                      dtype=jnp.int32)[:, None]) % mod)
            hash_join(hop_keys.reshape(-1), eqk, eqv, eqt)
            self.op_dispatches += 1
        found = eq_found & q_found
        # the kernel derives its valid flag from the joined rows' key lane:
        # mark misses so facts[:, -1] equals the probe's found mask
        eq_rows = eq_rows.at[:, 1].set(
            jnp.where(eq_found, eq_rows[:, 1], -1.0))
        q_rows = q_rows.at[:, 1].set(
            jnp.where(q_found, q_rows[:, 1], -1.0))
        # the fused kernel ALWAYS emits the per-unit aggregate; with
        # n_units requested it IS the rollup (one kernel produces facts +
        # KPI aggregate — the transform_and_rollup contract), otherwise the
        # epilogue is kept minimal and the aggregate dropped
        facts, agg = segment_kpi(padded, eq_rows, q_rows,
                                 n_units=n_units if n_units else 1)
        self.op_dispatches += 1
        rollup = agg if n_units else None
        return FactBlock(self, facts, found, n, rollup)

    def segment_reduce(self, facts, n_units):
        import jax.numpy as jnp
        from repro.kernels.segment_kpi.ops import segment_rollup
        facts = np.asarray(facts, np.float32)
        if not len(facts):
            return np.zeros((n_units, KPI_LANES), np.float32)
        # mutable=True: the pad-marking write below must never land in the
        # caller's array (power-of-two inputs used to come back aliased)
        padded = self._pad_bucket(facts, floor=256, mutable=True)
        padded[len(facts):, 9] = 0.0           # pad rows marked invalid
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(segment_rollup(jnp.asarray(padded),
                                         n_units=n_units))

    def fold_segments(self, seg_ids, values, n_segments):
        # fused kernel path: one-hot MXU matmul for count+sum, masked lane
        # reductions for min/max (see kernels/segment_kpi), over the
        # compacted segment range. The MXU's reduction order differs from
        # the halving tree, so this backend is parity-checked to ~1e-5,
        # not bitwise (same contract as the other pallas ops).
        def tree(s, v, ns):
            import jax.numpy as jnp
            from repro.kernels.segment_kpi.ops import fold_segments
            packed = jnp.concatenate(
                [jnp.asarray(s, jnp.float32)[:, None], jnp.asarray(v)],
                axis=1)
            self.op_dispatches += 1
            self.host_syncs += 1
            return np.asarray(fold_segments(packed, n_segments=ns))
        return _fold_blocks(seg_ids, values, n_segments, tree)

    def fold_segments_scan(self, seg_ids, values, n_segments):
        # the scan is an XLA structural op — there's no MXU-shaped inner
        # reduction left to kernelize — so this backend shares the jitted
        # scan path (bitwise equal to numpy/jax by the same bit-reversal
        # argument)
        return JaxBackend.fold_segments_scan(self, seg_ids, values,
                                             n_segments)

    def batch_gather_stats(self, table, seg_ids):
        import jax.numpy as jnp
        from repro.kernels.segment_kpi.ops import gather_stats
        idx = np.asarray(seg_ids, np.int64)
        if not len(idx):
            L = (np.asarray(table).shape[1] - 1) // 3
            return np.zeros((0, gather_width(L)), np.float32)
        self.op_dispatches += 1
        self.host_syncs += 1
        return np.asarray(gather_stats(
            jnp.asarray(np.asarray(table, np.float32)), idx))

    def prefix_fold(self, table):
        # same structural-op argument as fold_segments_scan
        return JaxBackend.prefix_fold(self, table)


__all__ = [
    "ComputeBackend", "FactBlock", "NumpyBackend", "JaxBackend",
    "PallasBackend", "register_backend", "get_backend",
    "available_backends", "resolve_backend_name", "DEFAULT_BACKEND",
    "ENV_VAR", "KPI_LANES", "FOLD_BLOCK", "fold_width", "gather_width",
    "empty_fold_state", "combine_fold", "bitrev_permutation",
    "prefix_fold_reference",
]
