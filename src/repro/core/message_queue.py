"""Partitioned publish/subscribe message queue (paper §3.1.1).

Kafka-shaped semantics, array-backed:
  * one topic per source table,
  * per-partition ordered logs with offsets,
  * consumer groups with committed offsets (restart = resume from commit),
  * *log compaction* for master topics: ``snapshot()`` returns the latest
    record per row key — the mechanism the In-memory Table Updater uses to
    (re)populate caches on bootstrap, failover and elastic reassignment.

On a TPU pod the broker role is played by host memory + ICI; the observable
contract (ordering per partition, at-least-once delivery, compaction) is
preserved so higher stages are transport-agnostic (paper §3.3:
technology-independence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partitioning import partition_of
from repro.core.records import RecordBatch


@dataclasses.dataclass
class TopicConfig:
    name: str
    table_id: int
    n_partitions: int
    partition_by: str            # "row_key" (master) | "business_key" (operational)
    compacted: bool = False      # master topics keep a latest-per-key view


class Partition:
    def __init__(self):
        self.batches: List[RecordBatch] = []
        self.length = 0

    def append(self, batch: RecordBatch):
        if len(batch):
            self.batches.append(batch)
            self.length += len(batch)

    def read(self, offset: int, max_records: Optional[int] = None
             ) -> RecordBatch:
        if offset >= self.length:
            return RecordBatch.empty()
        out, seen = [], 0
        budget = (self.length - offset if max_records is None else max_records)
        for b in self.batches:
            if seen + len(b) <= offset:
                seen += len(b)
                continue
            lo = max(0, offset - seen)
            take = b.take(np.arange(lo, len(b)))
            seen += len(b)
            out.append(take)
            if sum(len(o) for o in out) >= budget:
                break
        batch = RecordBatch.concat(out)
        if len(batch) > budget:
            batch = batch.take(np.arange(budget))
        return batch


class Topic:
    def __init__(self, cfg: TopicConfig):
        self.cfg = cfg
        self.partitions = [Partition() for _ in range(cfg.n_partitions)]
        # compaction index: row_key -> (txn_time, payload, business_key)
        self._compact: Dict[int, Tuple[int, np.ndarray, int]] = {}

    def publish(self, batch: RecordBatch) -> None:
        if not len(batch):
            return
        keys = (batch.row_key if self.cfg.partition_by == "row_key"
                else batch.business_key)
        parts = partition_of(keys, self.cfg.n_partitions)
        for p in range(self.cfg.n_partitions):
            idx = np.nonzero(parts == p)[0]
            if len(idx):
                self.partitions[p].append(batch.take(idx))
        if self.cfg.compacted:
            for i in range(len(batch)):
                rk = int(batch.row_key[i])
                t = int(batch.txn_time[i])
                prev = self._compact.get(rk)
                if prev is None or t >= prev[0]:
                    self._compact[rk] = (t, batch.payload[i],
                                         int(batch.business_key[i]))

    def snapshot(self, business_keys: Optional[set] = None
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compacted latest-per-row-key view, optionally filtered by the
        business keys assigned to the requesting worker (paper: the cache
        'only saves data related to the business keys assigned to its
        corresponding Stream Processor node'). Returns (row_keys, payloads,
        txn_times)."""
        assert self.cfg.compacted, "snapshot() requires a compacted topic"
        items = [(rk, v) for rk, v in self._compact.items()
                 if business_keys is None or v[2] in business_keys]
        if not items:
            from repro.core.records import PAYLOAD_WIDTH
            return (np.zeros(0, np.int64),
                    np.zeros((0, PAYLOAD_WIDTH), np.float32),
                    np.zeros(0, np.int64))
        rks = np.array([rk for rk, _ in items], np.int64)
        pls = np.stack([v[1] for _, v in items])
        tts = np.array([v[0] for _, v in items], np.int64)
        return rks, pls, tts

    def high_watermark(self, partition: int) -> int:
        return self.partitions[partition].length


class MessageQueue:
    """Broker: topics + consumer-group offsets (restartable consumption)."""

    def __init__(self):
        self.topics: Dict[str, Topic] = {}
        self.offsets: Dict[Tuple[str, str, int], int] = {}  # (group, topic, part)

    def create_topic(self, cfg: TopicConfig) -> Topic:
        self.topics[cfg.name] = Topic(cfg)
        return self.topics[cfg.name]

    def publish(self, topic: str, batch: RecordBatch) -> None:
        self.topics[topic].publish(batch)

    def consume(self, group: str, topic: str, partition: int,
                max_records: Optional[int] = None) -> RecordBatch:
        key = (group, topic, partition)
        off = self.offsets.get(key, 0)
        batch = self.topics[topic].partitions[partition].read(off, max_records)
        return batch

    def commit(self, group: str, topic: str, partition: int, n: int) -> None:
        key = (group, topic, partition)
        self.offsets[key] = self.offsets.get(key, 0) + n

    def lag(self, group: str, topic: str, partition: int) -> int:
        key = (group, topic, partition)
        return (self.topics[topic].high_watermark(partition)
                - self.offsets.get(key, 0))

    def committed(self, group: str, topic: str, partition: int) -> int:
        return self.offsets.get((group, topic, partition), 0)

    def restore_offsets(self, state: Dict) -> None:
        self.offsets.update({tuple(k.split("|")): v for k, v in state.items()}
                            if isinstance(next(iter(state), None), str)
                            else state)

    def export_offsets(self) -> Dict:
        return dict(self.offsets)
