"""Partitioned publish/subscribe message queue (paper §3.1.1).

Kafka-shaped semantics, array-backed:
  * one topic per source table,
  * per-partition ordered logs with offsets,
  * consumer groups with committed offsets (restart = resume from commit),
  * *log compaction* for master topics: ``snapshot()`` returns the latest
    record per row key — the mechanism the In-memory Table Updater uses to
    (re)populate caches on bootstrap, failover and elastic reassignment.

On a TPU pod the broker role is played by host memory + ICI; the observable
contract (ordering per partition, at-least-once delivery, compaction) is
preserved so higher stages are transport-agnostic (paper §3.3:
technology-independence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.partitioning import partition_of
from repro.core.records import RecordBatch


@dataclasses.dataclass
class TopicConfig:
    name: str
    table_id: int
    n_partitions: int
    partition_by: str            # "row_key" (master) | "business_key" (operational)
    compacted: bool = False      # master topics keep a latest-per-key view


class Partition:
    def __init__(self):
        self.batches: List[RecordBatch] = []
        self.length = 0

    def append(self, batch: RecordBatch):
        if len(batch):
            self.batches.append(batch)
            self.length += len(batch)

    def read(self, offset: int, max_records: Optional[int] = None
             ) -> RecordBatch:
        if offset >= self.length:
            return RecordBatch.empty()
        out, seen = [], 0
        budget = (self.length - offset if max_records is None else max_records)
        for b in self.batches:
            if seen + len(b) <= offset:
                seen += len(b)
                continue
            lo = max(0, offset - seen)
            take = b.take(np.arange(lo, len(b)))
            seen += len(b)
            out.append(take)
            if sum(len(o) for o in out) >= budget:
                break
        batch = RecordBatch.concat(out)
        if len(batch) > budget:
            batch = batch.take(np.arange(budget))
        return batch


class Topic:
    def __init__(self, cfg: TopicConfig):
        self.cfg = cfg
        self.partitions = [Partition() for _ in range(cfg.n_partitions)]
        # compaction index: row_key -> (txn_time, payload, business_key)
        self._compact: Dict[int, Tuple[int, np.ndarray, int]] = {}
        self._compact_view = None    # lazily materialized columnar snapshot

    def publish(self, batch: RecordBatch) -> None:
        if not len(batch):
            return
        key = ("row_key" if self.cfg.partition_by == "row_key"
               else "business_key")
        for p, part_batch in batch.split_by_partition(
                self.cfg.n_partitions, key=key):
            self.partitions[p].append(part_batch)
        if self.cfg.compacted:
            # within-batch winner per row key first (latest txn_time, arrival
            # order breaking ties — same rule as the per-record loop), then
            # one dict update per surviving key
            order = np.lexsort((np.arange(len(batch)), batch.txn_time,
                                batch.row_key))
            rks = batch.row_key[order]
            last = np.nonzero(np.append(rks[1:] != rks[:-1], True))[0]
            for i in order[last]:
                i = int(i)
                rk = int(batch.row_key[i])
                t = int(batch.txn_time[i])
                prev = self._compact.get(rk)
                if prev is None or t >= prev[0]:
                    self._compact[rk] = (t, batch.payload[i],
                                         int(batch.business_key[i]))
            self._compact_view = None

    def _compact_columns(self):
        """Columnar view of the compaction index (cached between publishes)
        as (row_keys, payloads, txn_times, business_keys)."""
        if self._compact_view is None:
            from repro.core.records import PAYLOAD_WIDTH
            if not self._compact:
                self._compact_view = (
                    np.zeros(0, np.int64),
                    np.zeros((0, PAYLOAD_WIDTH), np.float32),
                    np.zeros(0, np.int64), np.zeros(0, np.int64))
            else:
                vals = list(self._compact.values())
                self._compact_view = (
                    np.fromiter(self._compact.keys(), np.int64,
                                len(self._compact)),
                    np.stack([v[1] for v in vals]),
                    np.array([v[0] for v in vals], np.int64),
                    np.array([v[2] for v in vals], np.int64))
            for a in self._compact_view:
                a.flags.writeable = False   # callers get views, not copies
        return self._compact_view

    def snapshot(self, business_keys=None
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compacted latest-per-row-key view, optionally filtered by the
        business keys assigned to the requesting worker (paper: the cache
        'only saves data related to the business keys assigned to its
        corresponding Stream Processor node'). ``business_keys`` may be a
        set or a (sorted) integer array. Returns (row_keys, payloads,
        txn_times)."""
        assert self.cfg.compacted, "snapshot() requires a compacted topic"
        rks, pls, tts, bks = self._compact_columns()
        if business_keys is None or not len(rks):
            return rks, pls, tts
        from repro.core.partitioning import isin_sorted
        sel = np.unique(np.fromiter(business_keys, np.int64)
                        if not isinstance(business_keys, np.ndarray)
                        else business_keys)
        mask = isin_sorted(sel, bks)
        return rks[mask], pls[mask], tts[mask]

    def high_watermark(self, partition: int) -> int:
        return self.partitions[partition].length


class MessageQueue:
    """Broker: topics + consumer-group offsets (restartable consumption)."""

    def __init__(self):
        self.topics: Dict[str, Topic] = {}
        self.offsets: Dict[Tuple[str, str, int], int] = {}  # (group, topic, part)

    def create_topic(self, cfg: TopicConfig) -> Topic:
        self.topics[cfg.name] = Topic(cfg)
        return self.topics[cfg.name]

    def publish(self, topic: str, batch: RecordBatch) -> None:
        self.topics[topic].publish(batch)

    def consume(self, group: str, topic: str, partition: int,
                max_records: Optional[int] = None) -> RecordBatch:
        key = (group, topic, partition)
        off = self.offsets.get(key, 0)
        batch = self.topics[topic].partitions[partition].read(off, max_records)
        return batch

    def consume_many(self, group: str, topic: str, partitions,
                     max_records_per_partition: Optional[int] = None
                     ) -> Tuple[RecordBatch, Dict[int, int]]:
        """Coalesce reads across ``partitions`` into ONE columnar batch —
        the Stream Processor's single-dispatch micro-batch. Returns
        (batch, {partition: records_read}); offsets still advance per
        partition via ``commit`` so rebalance handoff stays exact."""
        out: List[RecordBatch] = []
        counts: Dict[int, int] = {}
        t = self.topics[topic]
        for p in partitions:
            off = self.offsets.get((group, topic, p), 0)
            if off >= t.partitions[p].length:     # drained: skip the read
                continue
            b = t.partitions[p].read(off, max_records_per_partition)
            if len(b):
                out.append(b)
                counts[p] = len(b)
        return RecordBatch.concat(out), counts

    def commit(self, group: str, topic: str, partition: int, n: int) -> None:
        key = (group, topic, partition)
        self.offsets[key] = self.offsets.get(key, 0) + n

    def lag(self, group: str, topic: str, partition: int) -> int:
        key = (group, topic, partition)
        return (self.topics[topic].high_watermark(partition)
                - self.offsets.get(key, 0))

    def committed(self, group: str, topic: str, partition: int) -> int:
        return self.offsets.get((group, topic, partition), 0)

    def restore_offsets(self, state: Dict) -> None:
        self.offsets.update({tuple(k.split("|")): v for k, v in state.items()}
                            if isinstance(next(iter(state), None), str)
                            else state)

    def export_offsets(self) -> Dict:
        return dict(self.offsets)
