"""Partitioned publish/subscribe message queue (paper §3.1.1).

Kafka-shaped semantics, array-backed:
  * one topic per source table,
  * per-partition ordered logs with offsets,
  * consumer groups with committed offsets (restart = resume from commit),
  * *log compaction* for master topics: ``snapshot()`` returns the latest
    record per row key — the mechanism the In-memory Table Updater uses to
    (re)populate caches on bootstrap, failover and elastic reassignment.

On a TPU pod the broker role is played by host memory + ICI; the observable
contract (ordering per partition, at-least-once delivery, compaction) is
preserved so higher stages are transport-agnostic (paper §3.3:
technology-independence).

Thread-safety contract (the concurrent runtime drives one broker from many
worker threads):

  * published batches are frozen (read-only columns), so consumers share
    views without copies or races,
  * per-topic locks guard the append path + compaction index; reads snapshot
    the batch list and do their numpy work outside the lock,
  * consumer-group offset state is split into *positions* (how far a group
    has READ, advanced by ``fetch_many``) and *commits* (how far it has
    durably PROCESSED, advanced by ``commit``). A worker that dies between
    fetch and commit simply abandons its positions: the new owner of its
    partitions resumes from the committed offset, so nothing is lost and
    nothing is double-loaded (commit happens after warehouse load, under the
    worker's commit lock).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.partitioning import RoutingTable
from repro.core.records import RecordBatch
from repro.observability.registry import (MetricsRegistry, MetricsShard)


@dataclasses.dataclass
class TopicConfig:
    name: str
    table_id: int
    n_partitions: int
    partition_by: str            # "row_key" (master) | "business_key" (operational)
    compacted: bool = False      # master topics keep a latest-per-key view


class Partition:
    def __init__(self):
        self.batches: List[RecordBatch] = []
        self.length = 0

    def append(self, batch: RecordBatch):
        if len(batch):
            # freeze THEN publish: once the batch is reachable by consumer
            # threads its columns are immutable; `length` is bumped last so
            # a concurrent reader never sees a length without its batch
            self.batches.append(batch.freeze())
            self.length += len(batch)

    def read(self, offset: int, max_records: Optional[int] = None
             ) -> RecordBatch:
        # snapshot once: `batches` only ever grows at the tail and `length`
        # is published after the append, so (list copy, length) read in this
        # order can only under-report — never return a half-appended batch
        batches = list(self.batches)
        lens = [len(b) for b in batches]
        length = min(self.length, sum(lens))
        if offset >= length:
            return RecordBatch.empty()
        budget = length - offset
        if max_records is not None:
            budget = min(budget, max_records)
        out, seen, taken = [], 0, 0
        for b, lb in zip(batches, lens):
            if seen + lb <= offset:
                seen += lb
                continue
            lo = max(0, offset - seen)
            hi = min(lb, lo + (budget - taken))
            out.append(b.slice(lo, hi))     # zero-copy view of frozen batch
            taken += hi - lo
            seen += lb
            if taken >= budget:
                break
        if len(out) == 1:
            return out[0]                   # still a view; batch is frozen
        return RecordBatch.concat(out)


class Topic:
    """Partitioned log + the topic's ROUTING state: a versioned
    ``RoutingTable`` decides which partition a published record lands in.
    Epoch changes are append-only history — records published under epoch
    E stay readable in the partitions E chose (partition logs never move);
    a historical epoch is *live* until every partition's consumer has
    committed past the high watermark recorded at the switch (its
    ``horizons``), at which point ``retire_epochs`` drops it and workers
    may release the key ranges only that epoch routed to them."""

    def __init__(self, cfg: TopicConfig,
                 metrics_shard: Optional[MetricsShard] = None):
        self.cfg = cfg
        # broker publish counters live on the metrics registry (one read
        # path with every other pipeline signal); the shard is this
        # topic's private write surface — increments happen under the
        # publish lock, which already serializes the only writer
        self.metrics = metrics_shard or MetricsShard(f"broker.{cfg.name}")
        self._pub_counter = self.metrics.counter(
            f"broker.{cfg.name}.published")
        self._key_load_counter = self.metrics.counter(
            f"broker.{cfg.name}.key_loads")
        self.metrics.gauge_fn(
            f"broker.{cfg.name}.high_watermark",
            lambda: sum(p.length for p in self.partitions))
        self.partitions = [Partition() for _ in range(cfg.n_partitions)]
        # compaction index: row_key -> (txn_time, payload, business_key)
        self._compact: Dict[int, Tuple[int, np.ndarray, int]] = {}
        self._compact_view = None    # lazily materialized columnar snapshot
        self._lock = threading.Lock()   # serializes appends + compaction
        self.routing = RoutingTable.static(cfg.n_partitions)
        # ((table, horizons), ...): still-live superseded epochs, newest
        # last; replaced wholesale (copy-on-write) so readers are lock-free
        self._history: Tuple[Tuple[RoutingTable, Tuple[int, ...]], ...] = ()
        # observed publish load: per partition and per business key — the
        # coordinator's input to SkewAwareStrategy.rebalanced_table.
        # Business keys are dense small ints in this deployment, so the
        # per-key counter is a lazily grown array updated with ONE
        # np.add.at per publish (a Python dict loop here would run under
        # the publish lock on every CDC extraction)
        self.partition_pub = np.zeros(cfg.n_partitions, np.int64)
        self._key_loads = np.zeros(0, np.int64)
        self._untracked_key_load = 0      # sparse/negative keys: not used
                                          # for skew splits, but counted

    def publish(self, batch: RecordBatch) -> None:
        if not len(batch):
            return
        key = ("row_key" if self.cfg.partition_by == "row_key"
               else "business_key")
        with self._lock:
            self._publish_locked(batch, key)

    def _publish_locked(self, batch: RecordBatch, key: str) -> None:
        for p, part_batch in batch.split_by_partition(
                self.cfg.n_partitions, key=key, router=self.routing):
            self.partitions[p].append(part_batch)
            self.partition_pub[p] += len(part_batch)
        self._pub_counter.inc(len(batch))
        if key == "business_key" and len(batch):
            self._key_load_counter.inc(len(batch))
            ks = batch.business_key
            lo, hi = int(ks.min()), int(ks.max())
            if lo >= 0 and hi < (1 << 20):
                if hi >= len(self._key_loads):
                    grown = np.zeros(hi + 1, np.int64)
                    grown[:len(self._key_loads)] = self._key_loads
                    self._key_loads = grown
                np.add.at(self._key_loads, ks, 1)
            else:
                self._untracked_key_load += len(ks)
        if self.cfg.compacted:
            self._compact_update(batch)

    def _compact_update(self, batch: RecordBatch) -> None:
        """Lock-held: fold one batch into the compaction index — within-
        batch winner per row key first (latest txn_time, arrival order
        breaking ties — same rule as the per-record loop), then one dict
        update per surviving key. Also the recovery path's replay step:
        last-writer-wins is associative over concatenation, so replaying
        journal segments in partition-log order rebuilds the index the
        original publishes built."""
        order = np.lexsort((np.arange(len(batch)), batch.txn_time,
                            batch.row_key))
        rks = batch.row_key[order]
        last = np.nonzero(np.append(rks[1:] != rks[:-1], True))[0]
        for i in order[last]:
            i = int(i)
            rk = int(batch.row_key[i])
            t = int(batch.txn_time[i])
            prev = self._compact.get(rk)
            if prev is None or t >= prev[0]:
                self._compact[rk] = (t, batch.payload[i],
                                     int(batch.business_key[i]))
        self._compact_view = None

    def _compact_columns(self):
        """Columnar view of the compaction index (cached between publishes)
        as (row_keys, payloads, txn_times, business_keys)."""
        if self._compact_view is None:
            from repro.core.records import PAYLOAD_WIDTH
            if not self._compact:
                self._compact_view = (
                    np.zeros(0, np.int64),
                    np.zeros((0, PAYLOAD_WIDTH), np.float32),
                    np.zeros(0, np.int64), np.zeros(0, np.int64))
            else:
                vals = list(self._compact.values())
                self._compact_view = (
                    np.fromiter(self._compact.keys(), np.int64,
                                len(self._compact)),
                    np.stack([v[1] for v in vals]),
                    np.array([v[0] for v in vals], np.int64),
                    np.array([v[2] for v in vals], np.int64))
            for a in self._compact_view:
                a.flags.writeable = False   # callers get views, not copies
        return self._compact_view

    def snapshot(self, business_keys=None
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Compacted latest-per-row-key view, optionally filtered by the
        business keys assigned to the requesting worker (paper: the cache
        'only saves data related to the business keys assigned to its
        corresponding Stream Processor node'). ``business_keys`` may be a
        set or a (sorted) integer array. Returns (row_keys, payloads,
        txn_times)."""
        assert self.cfg.compacted, "snapshot() requires a compacted topic"
        with self._lock:             # publishes mutate the compaction index
            rks, pls, tts, bks = self._compact_columns()
        if business_keys is None or not len(rks):
            return rks, pls, tts
        from repro.core.partitioning import isin_sorted
        sel = np.unique(np.fromiter(business_keys, np.int64)
                        if not isinstance(business_keys, np.ndarray)
                        else business_keys)
        mask = isin_sorted(sel, bks)
        return rks[mask], pls[mask], tts[mask]

    def high_watermark(self, partition: int) -> int:
        return self.partitions[partition].length

    # -------------------------------------------------------- routing epochs
    def set_routing(self, table: RoutingTable) -> None:
        """Switch to a new routing epoch. Under the publish lock, so the
        per-partition horizons (lengths at the switch) are exact: every
        record below a horizon was routed by the OLD table, everything at
        or above it by the new one. The old epoch joins the live history
        unless its partitions were still empty (nothing to drain)."""
        assert table.n_partitions <= len(self.partitions), \
            "routing table wider than the topic (expand first)"
        with self._lock:
            if table.epoch == self.routing.epoch and \
                    table.kind == self.routing.kind:
                return
            horizons = tuple(p.length for p in self.partitions)
            if any(horizons):
                self._history = self._history + ((self.routing, horizons),)
            self.routing = table

    def live_tables(self) -> Tuple[RoutingTable, ...]:
        """Current table plus every superseded epoch still draining —
        the union a worker's business-key filter must cover so records
        published under an old epoch keep finding their master rows.

        Lock-free, so the read ORDER matters against ``set_routing``
        (history append, THEN routing swap): reading ``routing`` first
        can only over-report (the pre-swap table shows up both as
        current and, post-append, in history — callers dedupe by epoch);
        the reverse order could miss the just-superseded epoch
        entirely."""
        cur = self.routing                # read BEFORE history (see above)
        hist = self._history              # atomic tuple read, no lock
        return tuple(t for t, _ in hist) + (cur,)

    def routing_signature(self) -> Tuple[int, int]:
        """(current epoch, live history length) — memo invalidation key
        for anything derived from ``live_tables``."""
        return (self.routing.epoch, len(self._history))

    def retire_epochs(self, committed: Dict[int, int]) -> bool:
        """Drop historical epochs whose records are all committed:
        ``committed[p]`` is the owning consumer group's committed offset
        for partition p. Returns True if anything retired."""
        with self._lock:
            keep = tuple(
                (t, hz) for t, hz in self._history
                if any(committed.get(p, 0) < h for p, h in enumerate(hz)))
            retired = len(keep) != len(self._history)
            self._history = keep
        return retired

    def load_stats(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(per-partition publish counts, observed business keys, counts)
        — the skew strategy's rebalance input."""
        with self._lock:
            parts = self.partition_pub.copy()
            keys = np.nonzero(self._key_loads)[0].astype(np.int64)
            counts = self._key_loads[keys]
        return parts, keys, counts

    def expand(self, n_partitions: int) -> None:
        """Elastic scale event: append empty partitions (existing logs
        never move — only a routing-table change sends keys their way)."""
        with self._lock:
            add = n_partitions - len(self.partitions)
            assert add >= 0, "partitions never shrink (logs are durable)"
            self.partitions.extend(Partition() for _ in range(add))
            self.partition_pub = np.concatenate(
                [self.partition_pub, np.zeros(add, np.int64)])
            self.cfg.n_partitions = n_partitions

    # ------------------------------------------------------------- durability
    def export_state(self, since: Optional[List[int]] = None
                     ) -> Tuple[Dict, Dict[str, Dict]]:
        """(meta, segments): everything the durability journal needs for
        this topic. ``segments`` holds the partition-log SUFFIXES past
        ``since`` (already-journaled lengths, per partition) as column
        dicts; ``meta`` holds the small full state — lengths, routing
        epoch + live history horizons, publish/key-load counters."""
        with self._lock:
            lengths = [p.length for p in self.partitions]
            marks = list(since or [])
            segments: Dict[str, Dict] = {}
            for p, part in enumerate(self.partitions):
                lo = marks[p] if p < len(marks) else 0
                if part.length > lo:
                    segments[str(p)] = part.read(lo).as_dict()
            meta = {
                "lengths": lengths,
                "n_partitions": int(self.cfg.n_partitions),
                "routing": routing_state(self.routing),
                "history": [{"table": routing_state(t),
                             "horizons": [int(h) for h in hz]}
                            for t, hz in self._history],
                "partition_pub": self.partition_pub.copy(),
                "key_loads": self._key_loads.copy(),
                "untracked": int(self._untracked_key_load),
            }
        return meta, segments

    def restore_state(self, meta: Dict,
                      segments: Dict[int, List[Dict]]) -> None:
        """Cold-restart restore: wipe and rebuild the partition logs from
        journal segments (appended DIRECTLY to their recorded partitions
        — never re-routed, since the records were routed by whatever
        epoch ruled at publish time), the compaction index by replaying
        the restored batches, and the routing/counter state verbatim.

        A partition's value may be a LIST of column dicts (the journal's
        accumulated across-step form) or a single column dict (the shape
        ``export_state`` emits — a direct export->restore round trip,
        e.g. broker migration without a journal in between)."""
        with self._lock:
            n = max(int(meta["n_partitions"]), len(self.partitions))
            self.cfg.n_partitions = n
            self.partitions = [Partition() for _ in range(n)]
            self._compact = {}
            self._compact_view = None
            for p, col_list in segments.items():
                if isinstance(col_list, dict):
                    col_list = [col_list]
                for cols in col_list:
                    batch = RecordBatch(
                        **{k: np.asarray(v) for k, v in cols.items()})
                    self.partitions[int(p)].append(batch)
                    if self.cfg.compacted:
                        self._compact_update(batch)
            self.routing = restore_routing(meta["routing"])
            self._history = tuple(
                (restore_routing(h["table"]), tuple(h["horizons"]))
                for h in meta["history"])
            pub = np.zeros(n, np.int64)
            src = np.asarray(meta["partition_pub"], np.int64)
            pub[:len(src)] = src
            self.partition_pub = pub
            self._key_loads = np.asarray(meta["key_loads"], np.int64).copy()
            self._untracked_key_load = int(meta["untracked"])


def routing_state(table: RoutingTable) -> Dict:
    """JSON/array-serializable form of one routing table."""
    out = {"epoch": int(table.epoch), "kind": table.kind,
           "n_partitions": int(table.n_partitions)}
    if table.kind == "points":
        out["points"] = np.asarray(table.points)
        out["owners"] = np.asarray(table.owners)
    return out


def restore_routing(state: Dict) -> RoutingTable:
    if state["kind"] == "points":
        return RoutingTable.from_points(
            np.asarray(state["points"], np.uint64),
            np.asarray(state["owners"], np.int32),
            int(state["n_partitions"]), int(state["epoch"]))
    return RoutingTable.static(int(state["n_partitions"]),
                               epoch=int(state["epoch"]))


class MessageQueue:
    """Broker: topics + consumer-group offsets (restartable consumption).

    ``offsets`` holds COMMITTED progress (durably processed, survives the
    consumer); ``positions`` holds READ progress (advanced by ``fetch_many``
    before the work is done). The gap between the two is a consumer's
    in-flight window — abandoned wholesale if the consumer dies."""

    def __init__(self, metrics: Optional[MetricsRegistry] = None):
        self.topics: Dict[str, Topic] = {}
        self.offsets: Dict[Tuple[str, str, int], int] = {}  # (group, topic, part)
        self.positions: Dict[Tuple[str, str, int], int] = {}
        self._olock = threading.RLock()
        # fenced consumer groups: an evicted-but-possibly-zombie worker's
        # group is fenced so a late commit/fetch from its wedged thread is
        # dropped (worker names — and therefore groups — are never reused)
        self._fenced: set = set()
        self.fenced_commits = 0
        self.fenced_fetches = 0
        # per-topic publish counters land on this registry — the pipeline
        # passes its own so broker signals share its one read path
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    def create_topic(self, cfg: TopicConfig) -> Topic:
        self.topics[cfg.name] = Topic(
            cfg, self.metrics.shard(f"broker.{cfg.name}"))
        return self.topics[cfg.name]

    def publish(self, topic: str, batch: RecordBatch) -> None:
        self.topics[topic].publish(batch)

    def consume(self, group: str, topic: str, partition: int,
                max_records: Optional[int] = None) -> RecordBatch:
        key = (group, topic, partition)
        with self._olock:
            off = self.offsets.get(key, 0)
        batch = self.topics[topic].partitions[partition].read(off, max_records)
        return batch

    def consume_many(self, group: str, topic: str, partitions,
                     max_records_per_partition: Optional[int] = None
                     ) -> Tuple[RecordBatch, Dict[int, int]]:
        """Coalesce reads across ``partitions`` into ONE columnar batch —
        the Stream Processor's single-dispatch micro-batch. Returns
        (batch, {partition: records_read}); offsets still advance per
        partition via ``commit`` so rebalance handoff stays exact."""
        out: List[RecordBatch] = []
        counts: Dict[int, int] = {}
        t = self.topics[topic]
        for p in partitions:
            with self._olock:
                off = self.offsets.get((group, topic, p), 0)
            if off >= t.partitions[p].length:     # drained: skip the read
                continue
            b = t.partitions[p].read(off, max_records_per_partition)
            if len(b):
                out.append(b)
                counts[p] = len(b)
        return RecordBatch.concat(out), counts

    def fetch_many(self, group: str, topic: str, partitions: Iterable[int],
                   max_records_per_partition: Optional[int] = None
                   ) -> Tuple[RecordBatch, Dict[int, int]]:
        """Position-advancing coalesced read (the concurrent runtime's
        ingest stage). Unlike ``consume_many`` this moves the group's READ
        position immediately, so the next fetch returns fresh records even
        though nothing has been committed yet; the records only count as
        processed when ``commit`` runs (after warehouse load). A fetch
        always resumes from ``max(position, committed)`` so a partition
        granted back after a rebalance never re-reads records the interim
        owner committed."""
        out: List[RecordBatch] = []
        counts: Dict[int, int] = {}
        with self._olock:
            if group in self._fenced:
                self.fenced_fetches += 1
                return RecordBatch.concat(out), counts
        t = self.topics[topic]
        for p in partitions:
            key = (group, topic, p)
            with self._olock:
                start = max(self.positions.get(key, 0),
                            self.offsets.get(key, 0))
                hw = t.partitions[p].length
                if start >= hw:
                    continue
                take = hw - start
                if max_records_per_partition is not None:
                    take = min(take, max_records_per_partition)
                self.positions[key] = start + take
            b = t.partitions[p].read(start, take)
            if len(b):
                out.append(b)
                counts[p] = len(b)
        return RecordBatch.concat(out), counts

    def commit(self, group: str, topic: str, partition: int, n: int) -> None:
        key = (group, topic, partition)
        with self._olock:
            if group in self._fenced:
                self.fenced_commits += 1
                return
            self.offsets[key] = self.offsets.get(key, 0) + n

    def fence_group(self, group: str) -> None:
        """Permanently fence a consumer group: subsequent commits and
        fetches from it are dropped. Called when a worker is forcibly
        evicted (hang/straggler) — its stage threads may still be wedged
        mid-loop and must not move offsets after ownership has been
        transferred to a survivor. Groups derive from worker names and
        names are never reused, so the fence never blocks a legitimate
        successor."""
        with self._olock:
            self._fenced.add(group)

    def is_fenced(self, group: str) -> bool:
        with self._olock:
            return group in self._fenced

    def rewind(self, group: str, topic: str, partition: int) -> None:
        """Drop a group's read-ahead: next fetch resumes from the committed
        offset (used when a worker dies with in-flight fetches)."""
        with self._olock:
            self.positions.pop((group, topic, partition), None)

    def lag(self, group: str, topic: str, partition: int) -> int:
        key = (group, topic, partition)
        with self._olock:
            return (self.topics[topic].high_watermark(partition)
                    - self.offsets.get(key, 0))

    def committed(self, group: str, topic: str, partition: int) -> int:
        with self._olock:
            return self.offsets.get((group, topic, partition), 0)

    def commit_lags(self, group: str) -> Dict[str, Dict[int, int]]:
        """Per topic -> partition: high watermark minus ``group``'s
        committed offset — the health snapshot's backlog read path. One
        offset-lock pass per topic; partition lengths are published
        monotonically, so each entry is exact at its own read instant
        and never torn."""
        out: Dict[str, Dict[int, int]] = {}
        for name, t in self.topics.items():
            with self._olock:
                out[name] = {
                    p: t.partitions[p].length
                    - self.offsets.get((group, name, p), 0)
                    for p in range(len(t.partitions))}
        return out

    def restore_offsets(self, state) -> None:
        """Accepts the dict form (keys either (group, topic, part) tuples
        or "group|topic|part" strings) or the journal's list-of-rows form
        ``[[group, topic, part, n], ...]``. String/row partition ids are
        parsed back to int — a str partition key would never match the
        int-keyed lookups every consume path performs."""
        with self._olock:
            if isinstance(state, list):
                for g, t, p, n in state:
                    self.offsets[(g, t, int(p))] = int(n)
            elif state and isinstance(next(iter(state)), str):
                for k, v in state.items():
                    g, t, p = k.split("|")
                    self.offsets[(g, t, int(p))] = int(v)
            else:
                self.offsets.update(state)
            self.positions.clear()   # read-ahead is not durable state

    def export_offsets(self) -> Dict:
        with self._olock:
            return dict(self.offsets)

    # ------------------------------------------------------------- durability
    def export_state(self, since: Optional[Dict[str, List[int]]] = None
                     ) -> Dict:
        """Broker state for the durability journal: per-topic meta (full,
        small) + partition-log suffixes past ``since[topic]`` (large,
        incremental) + committed offsets as rows. Read-ahead positions
        are deliberately NOT exported — a restart abandons them and
        resumes from the committed offsets (the same contract a worker
        death has always had)."""
        since = since or {}
        meta: Dict[str, Dict] = {}
        segments: Dict[str, Dict] = {}
        for name, t in self.topics.items():
            meta[name], segments[name] = t.export_state(since.get(name))
        with self._olock:
            offsets = [[g, tp, int(p), int(n)]
                       for (g, tp, p), n in self.offsets.items()]
        return {"meta": meta, "segments": segments, "offsets": offsets}

    def restore_broker_state(self, state: Dict) -> None:
        """Restore every topic's logs/routing/counters plus the committed
        offsets. ``state["segments"]`` is the journal-accumulated form:
        {topic: {partition: [column-dict, ...]}} in log order."""
        for name, meta in state["meta"].items():
            self.topics[name].restore_state(
                meta, state["segments"].get(name, {}))
        with self._olock:
            self.offsets.clear()
            self.positions.clear()
        self.restore_offsets(state["offsets"])
